//! Sparse Alt-Diff: the Table 4 path (constrained sparsemax & friends).
//!
//! Two x-update engines, picked automatically:
//!
//! 1. **Sherman–Morrison** when H = D + ρ·aaᵀ for diagonal D and a single
//!    dense equality row a (exactly the sparsemax/softmax structure of
//!    paper Table 3: H = (2+2ρ)I + ρ11ᵀ). O(n) per solve.
//! 2. **Matrix-free CG** otherwise: H = diag(P) + ρAᵀA + ρGᵀG applied via
//!    three spmv's, Jacobi-preconditioned, warm-started from the previous
//!    iterate (ADMM iterates drift slowly, so warm starts cut CG counts
//!    dramatically — the sparse analogue of "inheriting" the Hessian).

use super::{
    BackwardMode, Options, Param, Solution, TraceEntry, Vjp, VjpSolution,
};
use crate::error::Result;
use crate::linalg::{dot, norm2, Mat};
use crate::prob::SparseQp;
use crate::sparse::{cg, Csr, HessianOp};
use crate::warm::{AdjointSeed, WarmStart};

/// Forward-mode backward work buffers for the sparse path, allocated
/// once per solve and reused every iteration.
struct SparseJacWork {
    lxt: Mat,
    newjx: Mat,
    gjx: Mat,
    coljl: Vec<f64>,
    coljn: Vec<f64>,
    coljs: Vec<f64>,
    colbuf: Vec<f64>,
    xcol: Vec<f64>,
    jxcol: Vec<f64>,
    spmv: Vec<f64>,
}

impl SparseJacWork {
    fn new(n: usize, m: usize, p: usize, d: usize) -> Self {
        SparseJacWork {
            lxt: Mat::zeros(n, d),
            newjx: Mat::zeros(n, d),
            gjx: Mat::zeros(m, d),
            coljl: vec![0.0; p],
            coljn: vec![0.0; m],
            coljs: vec![0.0; m],
            colbuf: vec![0.0; n],
            xcol: vec![0.0; n],
            jxcol: vec![0.0; n],
            spmv: vec![0.0; m.max(p)],
        }
    }
}

/// x-update engine. `pub(crate)` so [`crate::batch::BatchedSparseAltDiff`]
/// can inherit the registration-time pick (and the Sherman–Morrison
/// caches) instead of re-deriving them.
#[derive(Clone)]
pub(crate) enum Engine {
    /// H = diag(d) + ρ a aᵀ ; cached: dinv, u = dinv*a, denom = 1 + ρ aᵀu.
    ShermanMorrison { dinv: Vec<f64>, u: Vec<f64>, denom: f64, rho: f64 },
    /// Matrix-free CG on the assembled operator.
    Cg { cg_tol: f64, cg_max: usize },
}

/// A registered sparse QP layer.
pub struct SparseAltDiff {
    /// The registered problem (CSR constraints, diagonal P).
    pub qp: SparseQp,
    /// ADMM penalty ρ (fixed at registration, like the dense path).
    pub rho: f64,
    pub(crate) engine: Engine,
    /// diag(P) (assembled into the CG operator's diagonal together with
    /// the ρ·diag(AᵀA/GᵀG) terms).
    pub(crate) hdiag_p: Vec<f64>,
}

impl SparseAltDiff {
    /// Register: pick the x-update engine from the constraint structure
    /// (Sherman–Morrison for the sparsemax shape, matrix-free CG
    /// otherwise).
    pub fn new(qp: SparseQp, rho: f64) -> Result<Self> {
        let n = qp.n();
        let engine = Self::pick_engine(&qp, rho);
        let hdiag_p = qp.pdiag.clone();
        assert_eq!(hdiag_p.len(), n);
        Ok(SparseAltDiff { qp, rho, engine, hdiag_p })
    }

    /// Detect the Sherman–Morrison structure: G has exactly one nonzero
    /// per row with value ±1 (box rows → GᵀG diagonal), and A is a single
    /// dense row. This is precisely the sparsemax/softmax constraint set.
    fn pick_engine(qp: &SparseQp, rho: f64) -> Engine {
        let n = qp.n();
        let box_like = qp.g.rows > 0
            && (0..qp.g.rows).all(|i| {
                let lo = qp.g.indptr[i];
                let hi = qp.g.indptr[i + 1];
                hi - lo == 1 && qp.g.values[lo].abs() == 1.0
            });
        if box_like && qp.a.rows == 1 && qp.a.nnz() == n {
            // d_i = P_ii + rho * (#box rows touching i)
            let mut d = qp.pdiag.clone();
            for &j in &qp.g.indices {
                d[j] += rho;
            }
            let arow: Vec<f64> = {
                let mut v = vec![0.0; n];
                for k in 0..qp.a.nnz() {
                    v[qp.a.indices[k]] = qp.a.values[k];
                }
                v
            };
            let dinv: Vec<f64> = d.iter().map(|&v| 1.0 / v).collect();
            let u: Vec<f64> =
                dinv.iter().zip(&arow).map(|(di, ai)| di * ai).collect();
            let denom = 1.0 + rho * dot(&arow, &u);
            return Engine::ShermanMorrison { dinv, u, denom, rho };
        }
        Engine::Cg { cg_tol: 1e-10, cg_max: 10 * n }
    }

    /// Apply H⁻¹ to `rhs` (in/out `x` doubles as CG warm start).
    fn hsolve(&self, rhs: &[f64], x: &mut [f64]) {
        match &self.engine {
            Engine::ShermanMorrison { dinv, u, denom, rho } => {
                // (D + ρ a aᵀ)⁻¹ r = D⁻¹r − u (ρ aᵀ D⁻¹ r)/denom
                //   with u = D⁻¹a; note aᵀD⁻¹r = uᵀr.
                let ur = dot(u, rhs);
                let coef = rho * ur / denom;
                for i in 0..x.len() {
                    x[i] = dinv[i] * rhs[i] - coef * u[i];
                }
            }
            Engine::Cg { cg_tol, cg_max } => {
                let op = HessianOp::new(
                    &self.hdiag_p,
                    &self.qp.a,
                    &self.qp.g,
                    self.rho,
                );
                // warm start from incoming x
                cg(&op, rhs, x, *cg_tol, *cg_max)
                    .expect("CG failed on SPD Hessian");
            }
        }
    }

    /// Solve + differentiate. Mirrors
    /// [`DenseAltDiff::solve_with`](super::DenseAltDiff::solve_with).
    pub fn solve_with(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        opts: &Options,
    ) -> Solution {
        self.solve_from(q, b, h, None, opts)
    }

    /// [`Self::solve_with`] resuming from a prior iterate triple — the
    /// sparse sibling of
    /// [`DenseAltDiff::solve_from`](super::DenseAltDiff::solve_from),
    /// with the same semantics: the warm slack is re-derived via the
    /// (6) projection, `warm = None` is bit-identical to the cold path,
    /// and warm + forward-mode Jacobians require `tol = 0`. On the CG
    /// engine the warm x additionally warm-starts the very first inner
    /// H-solve.
    pub fn solve_from(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
    ) -> Solution {
        let n = self.qp.n();
        let m = self.qp.h.len();
        let p = self.qp.b.len();
        let rho = self.rho;
        let q = q.unwrap_or(&self.qp.q);
        let b = b.unwrap_or(&self.qp.b);
        let h = h.unwrap_or(&self.qp.h);

        let mut x = vec![0.0; n];
        let mut s = vec![0.0; m];
        let mut lam = vec![0.0; p];
        let mut nu = vec![0.0; m];
        if let Some(w) = warm {
            assert!(
                opts.backward.forward_param().is_none() || opts.tol == 0.0,
                "warm starts with forward-mode Jacobians require tol = 0 \
                 (fixed-k); use BackwardMode::None/Adjoint for truncated \
                 warm solves"
            );
            assert_eq!(w.dims(), (n, p, m), "warm-start dimensions");
            x.copy_from_slice(&w.x);
            lam.copy_from_slice(&w.lam);
            nu.copy_from_slice(&w.nu);
            let mut gx0 = vec![0.0; m];
            self.qp.g.spmv_acc(&mut gx0, 1.0, &x);
            for i in 0..m {
                s[i] = (-nu[i] / rho - (gx0[i] - h[i])).max(0.0);
            }
        }

        let param = opts.backward.forward_param();
        let d = param.map(|pm| pm.dim(n, m, p));
        let mut jx = d.map(|d| Mat::zeros(n, d));
        let mut js = d.map(|d| Mat::zeros(m, d));
        let mut jl = d.map(|d| Mat::zeros(p, d));
        let mut jn = d.map(|d| Mat::zeros(m, d));
        let mut work = d.map(|d| SparseJacWork::new(n, m, p, d));

        let mut trace = Vec::new();
        let mut rhs = vec![0.0; n];
        let mut xprev = vec![0.0; n];
        let mut hms = vec![0.0; m];
        let mut gx = vec![0.0; m];
        let mut ax = vec![0.0; p];
        let mut iters = 0;
        let mut step_rel = f64::INFINITY;

        for k in 0..opts.max_iter {
            iters = k + 1;
            xprev.copy_from_slice(&x);

            // forward (5a)
            for i in 0..n {
                rhs[i] = -q[i];
            }
            self.qp.a.spmv_t_acc(&mut rhs, -1.0, &lam);
            self.qp.g.spmv_t_acc(&mut rhs, -1.0, &nu);
            self.qp.a.spmv_t_acc(&mut rhs, rho, b);
            for i in 0..m {
                hms[i] = h[i] - s[i];
            }
            self.qp.g.spmv_t_acc(&mut rhs, rho, &hms);
            self.hsolve(&rhs, &mut x);

            // (6), (5c), (5d)
            gx.iter_mut().for_each(|v| *v = 0.0);
            self.qp.g.spmv_acc(&mut gx, 1.0, &x);
            for i in 0..m {
                s[i] = (-nu[i] / rho - (gx[i] - h[i])).max(0.0);
            }
            ax.iter_mut().for_each(|v| *v = 0.0);
            self.qp.a.spmv_acc(&mut ax, 1.0, &x);
            for i in 0..p {
                lam[i] += rho * (ax[i] - b[i]);
            }
            for i in 0..m {
                nu[i] += rho * (gx[i] + s[i] - h[i]);
            }

            // backward (7)
            if let (Some(jx), Some(js), Some(jl), Some(jn), Some(w)) = (
                jx.as_mut(),
                js.as_mut(),
                jl.as_mut(),
                jn.as_mut(),
                work.as_mut(),
            ) {
                self.jacobian_step(
                    param.unwrap(),
                    &s,
                    jx,
                    js,
                    jl,
                    jn,
                    w,
                    rho,
                );
            }

            let dx: f64 = x
                .iter()
                .zip(&xprev)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            step_rel = dx / norm2(&xprev).max(1.0);
            if opts.trace {
                trace.push(TraceEntry {
                    iter: k,
                    step_rel,
                    jac_norm: jx.as_ref().map(|j| j.fro()).unwrap_or(0.0),
                });
            }
            if step_rel < opts.tol {
                break;
            }
        }

        Solution { x, s, lam, nu, jacobian: jx, iters, step_rel, trace }
    }

    /// Convenience: solve with the registered parameters θ.
    pub fn solve(&self, opts: &Options) -> Solution {
        self.solve_with(None, None, None, opts)
    }

    #[allow(clippy::too_many_arguments)]
    fn jacobian_step(
        &self,
        param: Param,
        s1: &[f64],
        jx: &mut Mat,
        js: &mut Mat,
        jl: &mut Mat,
        jn: &mut Mat,
        w: &mut SparseJacWork,
        rho: f64,
    ) {
        let n = self.qp.n();
        let d = jx.cols;
        // lxt = Aᵀ Jλ + Gᵀ Jν + ρGᵀ Js + const(θ), built column-wise with
        // spmv_t (CSR has no gemm; d is small in the sparse regimes).
        let lxt = &mut w.lxt;
        let coljl = &mut w.coljl;
        let coljn = &mut w.coljn;
        let coljs = &mut w.coljs;
        for c in 0..d {
            for i in 0..jl.rows {
                coljl[i] = jl[(i, c)];
            }
            for i in 0..jn.rows {
                coljn[i] = jn[(i, c)];
            }
            for i in 0..js.rows {
                coljs[i] = js[(i, c)];
            }
            let col = &mut w.colbuf;
            col.iter_mut().for_each(|v| *v = 0.0);
            self.qp.a.spmv_t_acc(col, 1.0, coljl);
            self.qp.g.spmv_t_acc(col, 1.0, coljn);
            self.qp.g.spmv_t_acc(col, rho, coljs);
            lxt.set_col(c, col);
        }
        match param {
            Param::Q => {
                for i in 0..n.min(d) {
                    lxt[(i, i)] += 1.0;
                }
            }
            Param::B => {
                // -ρAᵀ : column c is -ρ * (row c of A) scattered
                for r in 0..self.qp.a.rows.min(d) {
                    for k in self.qp.a.indptr[r]..self.qp.a.indptr[r + 1] {
                        lxt[(self.qp.a.indices[k], r)] -=
                            rho * self.qp.a.values[k];
                    }
                }
            }
            Param::H => {
                for r in 0..self.qp.g.rows.min(d) {
                    for k in self.qp.g.indptr[r]..self.qp.g.indptr[r + 1] {
                        lxt[(self.qp.g.indices[k], r)] -=
                            rho * self.qp.g.values[k];
                    }
                }
            }
        }
        // (7a): column-wise H⁻¹ apply (SM: O(nd); CG: warm-started per col)
        let colbuf = &mut w.colbuf;
        let xcol = &mut w.xcol;
        for c in 0..d {
            for i in 0..n {
                colbuf[i] = w.lxt[(i, c)];
                xcol[i] = -jx[(i, c)]; // warm start from previous -Jx col
            }
            self.hsolve(colbuf, xcol);
            for i in 0..n {
                w.newjx[(i, c)] = -xcol[i];
            }
        }
        std::mem::swap(jx, &mut w.newjx);

        // (7b)
        let gjx = &mut w.gjx;
        let jxcol = &mut w.jxcol;
        for c in 0..d {
            for i in 0..n {
                jxcol[i] = jx[(i, c)];
            }
            let g = &mut w.spmv[..js.rows];
            g.iter_mut().for_each(|v| *v = 0.0);
            self.qp.g.spmv_acc(g, 1.0, jxcol);
            gjx.set_col(c, g);
        }
        if param == Param::H {
            for i in 0..gjx.rows.min(d) {
                gjx[(i, i)] -= 1.0;
            }
        }
        for i in 0..js.rows {
            let gate = if s1[i] > 0.0 { 1.0 } else { 0.0 };
            for c in 0..d {
                js[(i, c)] = gate
                    * (-(1.0 / rho))
                    * (jn[(i, c)] + rho * gjx[(i, c)]);
            }
        }
        // (7c)
        for c in 0..d {
            for i in 0..n {
                jxcol[i] = jx[(i, c)];
            }
            let a = &mut w.spmv[..jl.rows];
            a.iter_mut().for_each(|v| *v = 0.0);
            self.qp.a.spmv_acc(a, 1.0, jxcol);
            for i in 0..jl.rows {
                jl[(i, c)] += rho * a[i];
            }
        }
        if param == Param::B {
            for i in 0..jl.rows.min(d) {
                jl[(i, i)] -= rho;
            }
        }
        // (7d)
        jn.axpy(rho, &w.gjx);
        jn.axpy(rho, js);
    }

    /// Reverse-mode backward against an already-solved forward pass —
    /// the sparse sibling of [`DenseAltDiff::vjp`](super::DenseAltDiff::vjp):
    /// same transposed recursion, with the H⁻¹ applies going through the
    /// registration-time engine (Sherman–Morrison O(n) per iteration, or
    /// warm-started matrix-free CG) and every constraint product a CSR
    /// spmv. Per-iteration cost is O(nnz + n) — independent of d.
    pub fn vjp(&self, slack: &[f64], v: &[f64], opts: &Options) -> Vjp {
        self.vjp_from(slack, v, None, opts).0
    }

    /// [`Self::vjp`] resuming the transposed recursion from a prior
    /// adjoint state and returning the final state for reuse — the
    /// sparse sibling of
    /// [`DenseAltDiff::vjp_from`](super::DenseAltDiff::vjp_from). The
    /// seed's z also warm-starts the first inner CG solve on the CG
    /// engine; `warm = None` is bit-identical to the cold [`Self::vjp`].
    pub fn vjp_from(
        &self,
        slack: &[f64],
        v: &[f64],
        warm: Option<&AdjointSeed>,
        opts: &Options,
    ) -> (Vjp, AdjointSeed) {
        let n = self.qp.n();
        let m = self.qp.h.len();
        let p = self.qp.b.len();
        let rho = self.rho;
        assert_eq!(slack.len(), m, "slack dimension");
        assert_eq!(v.len(), n, "v dimension");
        let gate: Vec<f64> =
            slack.iter().map(|&s| if s > 0.0 { 1.0 } else { 0.0 }).collect();

        // t = −H⁻¹v and seeds (vs, vl, vn) = (ρGt, At, Gt)
        let negv: Vec<f64> = v.iter().map(|&vi| -vi).collect();
        let mut t = vec![0.0; n];
        self.hsolve(&negv, &mut t);
        let mut vn = vec![0.0; m];
        self.qp.g.spmv_acc(&mut vn, 1.0, &t);
        let mut vl = vec![0.0; p];
        self.qp.a.spmv_acc(&mut vl, 1.0, &t);

        let mut ws: Vec<f64> = vn.iter().map(|&g| rho * g).collect();
        let mut wl = vl.clone();
        let mut wn = vn.clone();

        let mut z = vec![0.0; n];
        let seeded = warm.is_some();
        if let Some(seed) = warm {
            assert_eq!(seed.dims(), (n, p, m), "adjoint-seed dimensions");
            ws.copy_from_slice(&seed.ws);
            wl.copy_from_slice(&seed.wl);
            wn.copy_from_slice(&seed.wn);
            z.copy_from_slice(&seed.z);
        }
        let mut zprev = vec![0.0; n];
        let mut rhs = vec![0.0; n];
        let mut dws = vec![0.0; m];
        let mut ewn = vec![0.0; m];
        let mut gz = vec![0.0; m];
        let mut az = vec![0.0; p];
        let mut iters = 1;
        let mut step_rel = f64::INFINITY;

        // z = −H⁻¹(−Gᵀ(σ⊙wₛ) + ρAᵀw_λ + ρGᵀ((1−σ)⊙w_ν)); `z` is in/out
        // (warm start for the CG engine).
        let zstep = |rhs: &mut Vec<f64>,
                     z: &mut Vec<f64>,
                     dws: &mut Vec<f64>,
                     ewn: &mut Vec<f64>,
                     ws: &[f64],
                     wl: &[f64],
                     wn: &[f64]| {
            for i in 0..m {
                dws[i] = gate[i] * ws[i];
                ewn[i] = (1.0 - gate[i]) * wn[i];
            }
            rhs.iter_mut().for_each(|r| *r = 0.0);
            self.qp.g.spmv_t_acc(rhs, 1.0, dws);
            self.qp.a.spmv_t_acc(rhs, -rho, wl);
            self.qp.g.spmv_t_acc(rhs, -rho, ewn);
            self.hsolve(rhs, z);
        };

        for k in 1..opts.max_iter {
            zprev.copy_from_slice(&z);
            zstep(&mut rhs, &mut z, &mut dws, &mut ewn, &ws, &wl, &wn);
            gz.iter_mut().for_each(|g| *g = 0.0);
            self.qp.g.spmv_acc(&mut gz, 1.0, &z);
            az.iter_mut().for_each(|a| *a = 0.0);
            self.qp.a.spmv_acc(&mut az, 1.0, &z);
            for i in 0..m {
                wn[i] = (1.0 - gate[i]) * wn[i] + gz[i]
                    - gate[i] * ws[i] / rho
                    + vn[i];
                ws[i] = rho * gz[i] + rho * vn[i];
            }
            for i in 0..p {
                wl[i] += az[i] + vl[i];
            }
            iters = k + 1;
            let dz: f64 = z
                .iter()
                .zip(&zprev)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            step_rel = dz / norm2(&zprev).max(1.0);
            // seeded first iteration reproduces the harvested z (zero
            // step under unchanged gates) — require one genuine step
            if step_rel < opts.tol && (k > 1 || !seeded) {
                break;
            }
        }
        zstep(&mut rhs, &mut z, &mut dws, &mut ewn, &ws, &wl, &wn);

        // the reusable adjoint state, harvested before the projection
        let seed_out = AdjointSeed {
            z: z.clone(),
            ws: ws.clone(),
            wl: wl.clone(),
            wn: wn.clone(),
        };

        let zt: Vec<f64> =
            z.iter().zip(&t).map(|(zi, ti)| zi + ti).collect();
        let mut grad_b: Vec<f64> = wl.iter().map(|&w| -rho * w).collect();
        self.qp.a.spmv_acc(&mut grad_b, -rho, &zt);
        let mut grad_h: Vec<f64> = (0..m)
            .map(|i| gate[i] * ws[i] - rho * (1.0 - gate[i]) * wn[i])
            .collect();
        self.qp.g.spmv_acc(&mut grad_h, -rho, &zt);
        (Vjp { grad_q: zt, grad_b, grad_h, iters, step_rel }, seed_out)
    }

    /// Forward solve + reverse-mode backward in one call (the training
    /// entry point) — see [`DenseAltDiff::solve_vjp`](super::DenseAltDiff::solve_vjp).
    pub fn solve_vjp(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        v: &[f64],
        opts: &Options,
    ) -> VjpSolution {
        let fopts =
            Options { backward: BackwardMode::None, ..opts.clone() };
        let solution = self.solve_with(q, b, h, &fopts);
        let vjp = self.vjp(&solution.s, v, opts);
        VjpSolution { solution, vjp }
    }

    /// True when the Sherman–Morrison fast path is active.
    pub fn uses_sherman_morrison(&self) -> bool {
        matches!(self.engine, Engine::ShermanMorrison { .. })
    }
}

/// Build a sparse layer directly from CSR parts (public convenience).
pub fn sparse_layer(
    pdiag: Vec<f64>,
    q: Vec<f64>,
    a: Csr,
    b: Vec<f64>,
    g: Csr,
    h: Vec<f64>,
    rho: f64,
) -> Result<SparseAltDiff> {
    SparseAltDiff::new(SparseQp { pdiag, q, a, b, g, h }, rho)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::altdiff::DenseAltDiff;
    use crate::prob::{sparse_qp, sparsemax_qp};

    #[test]
    fn sparsemax_uses_sherman_morrison() {
        let s = SparseAltDiff::new(sparsemax_qp(50, 1), 1.0).unwrap();
        assert!(s.uses_sherman_morrison());
        let r = SparseAltDiff::new(sparse_qp(30, 10, 4, 0.1, 1), 1.0)
            .unwrap();
        assert!(!r.uses_sherman_morrison());
    }

    #[test]
    fn sparsemax_solution_is_simplex_point() {
        let s = SparseAltDiff::new(sparsemax_qp(40, 2), 1.0).unwrap();
        let sol = s.solve(&Options {
            tol: 1e-10,
            max_iter: 50_000,
            backward: BackwardMode::None,
            ..Default::default()
        });
        let sum: f64 = sol.x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "simplex sum {sum}");
        for (i, &xi) in sol.x.iter().enumerate() {
            assert!(xi >= -1e-7, "x[{i}]={xi} below 0");
            assert!(xi <= s.qp.h[40 + i] + 1e-6, "x[{i}] above cap");
        }
    }

    #[test]
    fn sparse_matches_dense_solution_and_jacobian() {
        let sq = sparse_qp(18, 9, 4, 0.3, 3);
        let dense = DenseAltDiff::new(sq.to_dense(), 1.0).unwrap();
        let sparse = SparseAltDiff::new(sq, 1.0).unwrap();
        let opts = Options {
            tol: 1e-11,
            max_iter: 40_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        };
        let sd = dense.solve(&opts);
        let ss = sparse.solve(&opts);
        for i in 0..18 {
            assert!(
                (sd.x[i] - ss.x[i]).abs() < 1e-6,
                "x[{i}] {} vs {}",
                sd.x[i],
                ss.x[i]
            );
        }
        let jd = sd.jacobian.unwrap();
        let js = ss.jacobian.unwrap();
        assert!(jd.max_abs_diff(&js) < 1e-5);
    }

    #[test]
    fn sherman_morrison_matches_cg_on_same_structure() {
        // force CG by perturbing one G row to two entries, compare with a
        // dense assembly of the SM problem
        let sq = sparsemax_qp(12, 4);
        let dense = DenseAltDiff::new(sq.to_dense(), 1.0).unwrap();
        let sm = SparseAltDiff::new(sq, 1.0).unwrap();
        assert!(sm.uses_sherman_morrison());
        let opts = Options {
            tol: 1e-11,
            max_iter: 60_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        };
        let a = sm.solve(&opts);
        let b = dense.solve(&opts);
        for i in 0..12 {
            assert!((a.x[i] - b.x[i]).abs() < 1e-6);
        }
        assert!(a
            .jacobian
            .unwrap()
            .max_abs_diff(&b.jacobian.unwrap())
            < 1e-5);
    }

    #[test]
    fn jacobian_b_finite_difference_sparse() {
        let sq = sparse_qp(14, 7, 3, 0.25, 5);
        let s = SparseAltDiff::new(sq, 1.0).unwrap();
        let opts = Options {
            tol: 1e-11,
            max_iter: 40_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        };
        let sol = s.solve(&opts);
        let j = sol.jacobian.unwrap();
        let fopts = Options { backward: BackwardMode::None, ..opts };
        let eps = 1e-5;
        for c in 0..3 {
            let mut bp = s.qp.b.clone();
            bp[c] += eps;
            let mut bm = s.qp.b.clone();
            bm[c] -= eps;
            let xp = s.solve_with(None, Some(&bp), None, &fopts).x;
            let xm = s.solve_with(None, Some(&bm), None, &fopts).x;
            for i in 0..14 {
                let fd = (xp[i] - xm[i]) / (2.0 * eps);
                assert!(
                    (j[(i, c)] - fd).abs() < 2e-3 * (1.0 + fd.abs()),
                    "J[{i},{c}]={} fd={fd}",
                    j[(i, c)]
                );
            }
        }
    }
}
