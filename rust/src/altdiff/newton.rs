//! General-objective Alt-Diff (paper §4.2 "general cases", Table 5):
//! the x-update (5a) has no closed form, so each ADMM iteration runs an
//! inner (damped) Newton solve; the *final inner Hessian* is inherited by
//! the backward step (7a) — Appendix B.1's argument in the general case.
//!
//! Fast path: when ∇²f is diagonal and the constraints have the
//! softmax/sparsemax structure (one dense equality row, box inequalities)
//! the Newton system H = diag + ρ11ᵀ is solved by Sherman–Morrison in
//! O(n) (paper Table 3's closed form for the constrained Softmax layer).

use super::{BackwardMode, Options, Param, Solution, TraceEntry};
use crate::error::Result;
use crate::linalg::{dot, norm2, Chol, Mat};
use crate::prob::{Objective, SparseQp};
use crate::sparse::Csr;

/// A registered general-objective layer with polyhedral constraints.
pub struct NewtonAltDiff<O: Objective> {
    /// The convex objective f.
    pub obj: O,
    /// Equality constraint matrix A, (p,n) CSR.
    pub a: Csr,
    /// Equality right-hand side b, (p).
    pub b: Vec<f64>,
    /// Inequality constraint matrix G, (m,n) CSR.
    pub g: Csr,
    /// Inequality right-hand side h, (m).
    pub h: Vec<f64>,
    /// ADMM penalty ρ.
    pub rho: f64,
    /// max inner Newton iterations per ADMM step
    pub newton_max: usize,
    /// inner gradient tolerance
    pub newton_tol: f64,
    sm_structured: bool,
}

impl<O: Objective> NewtonAltDiff<O> {
    /// Register: detect the softmax/sparsemax structure for the O(n)
    /// Sherman–Morrison inner solves.
    pub fn new(
        obj: O,
        a: Csr,
        b: Vec<f64>,
        g: Csr,
        h: Vec<f64>,
        rho: f64,
    ) -> Result<Self> {
        let n = a.cols;
        let box_like = g.rows > 0
            && (0..g.rows).all(|i| {
                let lo = g.indptr[i];
                let hi = g.indptr[i + 1];
                hi - lo == 1 && g.values[lo].abs() == 1.0
            });
        let sm_structured = box_like && a.rows == 1 && a.nnz() == n;
        Ok(NewtonAltDiff {
            obj,
            a,
            b,
            g,
            h,
            rho,
            newton_max: 50,
            newton_tol: 1e-10,
            sm_structured,
        })
    }

    /// From a SparseQp-shaped constraint block.
    pub fn from_parts(obj: O, qp: &SparseQp, rho: f64) -> Result<Self> {
        Self::new(
            obj,
            qp.a.clone(),
            qp.b.clone(),
            qp.g.clone(),
            qp.h.clone(),
            rho,
        )
    }

    /// ∇L(x) for fixed (s, λ, ν).
    fn lag_grad(
        &self,
        x: &[f64],
        s: &[f64],
        lam: &[f64],
        nu: &[f64],
    ) -> Vec<f64> {
        let mut grad = self.obj.grad(x);
        self.a.spmv_t_acc(&mut grad, 1.0, lam);
        self.g.spmv_t_acc(&mut grad, 1.0, nu);
        // ρAᵀ(Ax−b)
        let mut ax = self.a.spmv(x);
        for (axi, bi) in ax.iter_mut().zip(&self.b) {
            *axi -= bi;
        }
        self.a.spmv_t_acc(&mut grad, self.rho, &ax);
        // ρGᵀ(Gx+s−h)
        let mut gx = self.g.spmv(x);
        for i in 0..gx.len() {
            gx[i] += s[i] - self.h[i];
        }
        self.g.spmv_t_acc(&mut grad, self.rho, &gx);
        grad
    }

    /// Solve H d = -grad where H = ∇²f(x) + ρAᵀA + ρGᵀG.
    /// Returns (d, HessianHandle for the backward reuse).
    fn newton_dir(&self, x: &[f64], grad: &[f64]) -> (Vec<f64>, HessH) {
        let n = x.len();
        if self.sm_structured {
            if let Some(hd) = self.obj.hess_diag(x) {
                // d_i = hd_i + ρ * (#box rows on i); plus ρ a aᵀ
                let mut dvec = hd;
                for &j in &self.g.indices {
                    dvec[j] += self.rho;
                }
                let mut arow = vec![0.0; n];
                for k in 0..self.a.nnz() {
                    arow[self.a.indices[k]] = self.a.values[k];
                }
                let dinv: Vec<f64> =
                    dvec.iter().map(|&v| 1.0 / v).collect();
                let u: Vec<f64> = dinv
                    .iter()
                    .zip(&arow)
                    .map(|(di, ai)| di * ai)
                    .collect();
                let denom = 1.0 + self.rho * dot(&arow, &u);
                let hh = HessH::Sm { dinv, u, denom, rho: self.rho };
                let mut d = vec![0.0; n];
                hh.solve(grad, &mut d);
                for v in &mut d {
                    *v = -*v;
                }
                return (d, hh);
            }
        }
        // dense assembly fallback
        let mut hmat = self.obj.hess(x);
        let ata = self.a.ata().to_dense();
        let gtg = self.g.ata().to_dense();
        hmat.axpy(self.rho, &ata);
        hmat.axpy(self.rho, &gtg);
        let ch = Chol::factor(&hmat).expect("Lagrangian Hessian SPD");
        let mut d = ch.solve(grad);
        for v in &mut d {
            *v = -*v;
        }
        (d, HessH::Dense(ch))
    }

    /// Inner Newton for (5a) with domain-respecting backtracking.
    /// Returns the final Hessian handle for backward reuse.
    fn x_update(
        &self,
        x: &mut Vec<f64>,
        s: &[f64],
        lam: &[f64],
        nu: &[f64],
    ) -> HessH {
        let mut hh = None;
        for _ in 0..self.newton_max {
            let grad = self.lag_grad(x, s, lam, nu);
            if norm2(&grad) < self.newton_tol {
                break;
            }
            let (dir, handle) = self.newton_dir(x, &grad);
            hh = Some(handle);
            // backtracking: stay in the objective's domain (entropy: x>0)
            // and require gradient-norm progress (sufficient for the
            // strongly-convex inner problems here).
            let g0 = norm2(&grad);
            let mut alpha = 1.0;
            for _ in 0..40 {
                let cand: Vec<f64> = x
                    .iter()
                    .zip(&dir)
                    .map(|(xi, di)| xi + alpha * di)
                    .collect();
                let in_domain = self
                    .obj
                    .hess_diag(&cand)
                    .map(|d| d.iter().all(|v| v.is_finite()))
                    .unwrap_or(true)
                    && cand.iter().all(|v| v.is_finite());
                // entropy domain: grad finite requires x > 0
                let dom_ok = in_domain
                    && self.obj.grad(&cand).iter().all(|v| v.is_finite());
                if dom_ok {
                    let g1 = norm2(&self.lag_grad(&cand, s, lam, nu));
                    if g1 < g0 {
                        *x = cand;
                        break;
                    }
                }
                alpha *= 0.5;
            }
            if alpha < 1e-11 {
                break;
            }
        }
        hh.unwrap_or_else(|| {
            // converged immediately: still need the Hessian for backward
            let grad = vec![0.0; x.len()];
            self.newton_dir(x, &grad).1
        })
    }

    /// Full Alt-Diff loop. `param` semantics: Param::Q differentiates
    /// w.r.t. a linear coefficient c appearing as +cᵀx in f — for the
    /// entropy objective f = −yᵀx + Σx log x, ∂x/∂y = −(∂x/∂c).
    pub fn solve(&self, opts: &Options) -> Solution {
        let n = self.a.cols;
        let m = self.h.len();
        let p = self.b.len();
        let rho = self.rho;
        let mut x = self.obj.domain_start(n);
        let mut s = vec![0.0; m];
        let mut lam = vec![0.0; p];
        let mut nu = vec![0.0; m];

        let d = opts.backward.forward_param().map(|pm| pm.dim(n, m, p));
        let mut jx = d.map(|d| Mat::zeros(n, d));
        let mut js = d.map(|d| Mat::zeros(m, d));
        let mut jl = d.map(|d| Mat::zeros(p, d));
        let mut jn = d.map(|d| Mat::zeros(m, d));

        let mut trace = Vec::new();
        let mut xprev = x.clone();
        let mut iters = 0;
        let mut step_rel = f64::INFINITY;

        for k in 0..opts.max_iter {
            iters = k + 1;
            xprev.copy_from_slice(&x);

            let hess = self.x_update(&mut x, &s, &lam, &nu);

            let gx = self.g.spmv(&x);
            for i in 0..m {
                s[i] = (-nu[i] / rho - (gx[i] - self.h[i])).max(0.0);
            }
            let ax = self.a.spmv(&x);
            for i in 0..p {
                lam[i] += rho * (ax[i] - self.b[i]);
            }
            for i in 0..m {
                nu[i] += rho * (gx[i] + s[i] - self.h[i]);
            }

            if let (Some(jx), Some(js), Some(jl), Some(jn)) =
                (jx.as_mut(), js.as_mut(), jl.as_mut(), jn.as_mut())
            {
                self.jacobian_step(
                    opts.backward.forward_param().unwrap(),
                    &hess,
                    &s,
                    jx,
                    js,
                    jl,
                    jn,
                );
            }

            let dx: f64 = x
                .iter()
                .zip(&xprev)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            step_rel = dx / norm2(&xprev).max(1.0);
            if opts.trace {
                trace.push(TraceEntry {
                    iter: k,
                    step_rel,
                    jac_norm: jx.as_ref().map(|j| j.fro()).unwrap_or(0.0),
                });
            }
            if step_rel < opts.tol {
                break;
            }
        }

        Solution { x, s, lam, nu, jacobian: jx, iters, step_rel, trace }
    }

    fn jacobian_step(
        &self,
        param: Param,
        hess: &HessH,
        s1: &[f64],
        jx: &mut Mat,
        js: &mut Mat,
        jl: &mut Mat,
        jn: &mut Mat,
    ) {
        let rho = self.rho;
        let n = self.a.cols;
        let d = jx.cols;
        let mut lxt = Mat::zeros(n, d);
        let mut coljl = vec![0.0; jl.rows];
        let mut coljn = vec![0.0; jn.rows];
        let mut coljs = vec![0.0; js.rows];
        for c in 0..d {
            for i in 0..jl.rows {
                coljl[i] = jl[(i, c)];
            }
            for i in 0..jn.rows {
                coljn[i] = jn[(i, c)];
            }
            for i in 0..js.rows {
                coljs[i] = js[(i, c)];
            }
            let mut col = vec![0.0; n];
            self.a.spmv_t_acc(&mut col, 1.0, &coljl);
            self.g.spmv_t_acc(&mut col, 1.0, &coljn);
            self.g.spmv_t_acc(&mut col, rho, &coljs);
            lxt.set_col(c, &col);
        }
        match param {
            Param::Q => {
                for i in 0..n.min(d) {
                    lxt[(i, i)] += 1.0;
                }
            }
            Param::B => {
                for r in 0..self.a.rows.min(d) {
                    for k in self.a.indptr[r]..self.a.indptr[r + 1] {
                        lxt[(self.a.indices[k], r)] -=
                            rho * self.a.values[k];
                    }
                }
            }
            Param::H => {
                for r in 0..self.g.rows.min(d) {
                    for k in self.g.indptr[r]..self.g.indptr[r + 1] {
                        lxt[(self.g.indices[k], r)] -=
                            rho * self.g.values[k];
                    }
                }
            }
        }
        let mut newjx = Mat::zeros(n, d);
        let mut colbuf = vec![0.0; n];
        let mut out = vec![0.0; n];
        for c in 0..d {
            for i in 0..n {
                colbuf[i] = lxt[(i, c)];
            }
            hess.solve(&colbuf, &mut out);
            for i in 0..n {
                newjx[(i, c)] = -out[i];
            }
        }
        *jx = newjx;

        let mut gjx = Mat::zeros(js.rows, d);
        let mut jxcol = vec![0.0; n];
        for c in 0..d {
            for i in 0..n {
                jxcol[i] = jx[(i, c)];
            }
            gjx.set_col(c, &self.g.spmv(&jxcol));
        }
        if param == Param::H {
            for i in 0..gjx.rows.min(d) {
                gjx[(i, i)] -= 1.0;
            }
        }
        for i in 0..js.rows {
            let gate = if s1[i] > 0.0 { 1.0 } else { 0.0 };
            for c in 0..d {
                js[(i, c)] = gate
                    * (-(1.0 / rho))
                    * (jn[(i, c)] + rho * gjx[(i, c)]);
            }
        }
        for c in 0..d {
            for i in 0..n {
                jxcol[i] = jx[(i, c)];
            }
            let a = self.a.spmv(&jxcol);
            for i in 0..jl.rows {
                jl[(i, c)] += rho * a[i];
            }
        }
        if param == Param::B {
            for i in 0..jl.rows.min(d) {
                jl[(i, i)] -= rho;
            }
        }
        jn.axpy(rho, &gjx);
        jn.axpy(rho, js);
    }
}

/// Handle to the inner Hessian, reused by the backward pass.
enum HessH {
    Sm { dinv: Vec<f64>, u: Vec<f64>, denom: f64, rho: f64 },
    Dense(Chol),
}

impl HessH {
    fn solve(&self, rhs: &[f64], out: &mut [f64]) {
        match self {
            HessH::Sm { dinv, u, denom, rho } => {
                let ur = dot(u, rhs);
                let coef = rho * ur / denom;
                for i in 0..out.len() {
                    out[i] = dinv[i] * rhs[i] - coef * u[i];
                }
            }
            HessH::Dense(ch) => {
                out.copy_from_slice(rhs);
                ch.solve_in_place(out);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::{softmax_layer, EntropyObjective};

    fn softmax_solver(n: usize, seed: u64) -> NewtonAltDiff<EntropyObjective>
    {
        let (y, u) = softmax_layer(n, seed);
        let ones: Vec<(usize, usize, f64)> =
            (0..n).map(|j| (0, j, 1.0)).collect();
        let a = Csr::from_triplets(1, n, &ones);
        let mut gt = Vec::new();
        for i in 0..n {
            gt.push((i, i, -1.0));
            gt.push((n + i, i, 1.0));
        }
        let g = Csr::from_triplets(2 * n, n, &gt);
        let mut h = vec![0.0; 2 * n];
        for i in 0..n {
            h[n + i] = u[i];
        }
        NewtonAltDiff::new(EntropyObjective { y }, a, vec![1.0], g, h, 1.0)
            .unwrap()
    }

    #[test]
    fn softmax_layer_converges_to_simplex_point() {
        let s = softmax_solver(15, 1);
        assert!(s.sm_structured);
        let sol = s.solve(&Options {
            tol: 1e-9,
            max_iter: 20_000,
            backward: BackwardMode::None,
            ..Default::default()
        });
        let sum: f64 = sol.x.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5, "sum {sum}");
        assert!(sol.x.iter().all(|&v| v > 0.0));
        for i in 0..15 {
            assert!(sol.x[i] <= s.h[15 + i] + 1e-6);
        }
    }

    #[test]
    fn unconstrained_cap_softmax_matches_closed_form() {
        // with caps u >= 1 the box never binds and the solution is the
        // classic softmax(y) (KKT: log x_i + 1 - y_i + lam = 0).
        let n = 8;
        let (y, _) = softmax_layer(n, 2);
        let ones: Vec<(usize, usize, f64)> =
            (0..n).map(|j| (0, j, 1.0)).collect();
        let a = Csr::from_triplets(1, n, &ones);
        let mut gt = Vec::new();
        for i in 0..n {
            gt.push((i, i, -1.0));
            gt.push((n + i, i, 1.0));
        }
        let g = Csr::from_triplets(2 * n, n, &gt);
        let mut h = vec![0.0; 2 * n];
        for i in 0..n {
            h[n + i] = 2.0; // cap never active
        }
        let s = NewtonAltDiff::new(
            EntropyObjective { y: y.clone() },
            a,
            vec![1.0],
            g,
            h,
            1.0,
        )
        .unwrap();
        let sol = s.solve(&Options {
            tol: 1e-10,
            max_iter: 30_000,
            backward: BackwardMode::None,
            ..Default::default()
        });
        let mx = y.iter().cloned().fold(f64::MIN, f64::max);
        let z: f64 = y.iter().map(|v| (v - mx).exp()).sum();
        for i in 0..n {
            let want = (y[i] - mx).exp() / z;
            assert!(
                (sol.x[i] - want).abs() < 1e-4,
                "x[{i}]={} softmax={want}",
                sol.x[i]
            );
        }
    }

    #[test]
    fn jacobian_q_finite_difference_entropy() {
        let n = 10;
        let s = softmax_solver(n, 3);
        let opts = Options {
            tol: 1e-11,
            max_iter: 40_000,
            backward: BackwardMode::Forward(Param::Q),
            ..Default::default()
        };
        let sol = s.solve(&opts);
        let j = sol.jacobian.as_ref().unwrap();
        // Param::Q is d/dc with f = cᵀx + entropy; here c = -y, so
        // dx/dy = -J. Check against FD on y.
        let eps = 1e-5;
        let fopts = Options { backward: BackwardMode::None, ..opts.clone() };
        for c in [0usize, 5] {
            let mut sp = softmax_solver(n, 3);
            sp.obj.y[c] += eps;
            let mut sm = softmax_solver(n, 3);
            sm.obj.y[c] -= eps;
            let xp = sp.solve(&fopts).x;
            let xm = sm.solve(&fopts).x;
            for i in 0..n {
                let fd = (xp[i] - xm[i]) / (2.0 * eps);
                let got = -j[(i, c)];
                assert!(
                    (got - fd).abs() < 5e-3 * (1.0 + fd.abs()),
                    "dx{i}/dy{c}: got {got} fd {fd}"
                );
            }
        }
    }
}
