//! Dense-QP Alt-Diff: the Table 2 path.
//!
//! Registration factors H = P + ρAᵀA + ρGᵀG once (Cholesky, O(n³/3));
//! every forward iteration is then one O(n²) triangular solve plus
//! matvecs, and every backward iteration is O(n²·d) gemm work against the
//! same factor — the paper's "inheritance of the Hessian" (Appendix B.1)
//! and its O(kn²) backward complexity claim (Table 1).

use super::{
    BackwardMode, Options, Param, Solution, TraceEntry, Vjp, VjpSolution,
};
use crate::error::Result;
use crate::linalg::{
    self, gemm_acc, gemv_acc, gemv_t_acc, norm2, Chol, Mat,
};
use crate::obs::IterObserver;
use crate::prob::Qp;
use crate::warm::{AdjointSeed, WarmStart};

/// A registered dense QP layer: problem structure + cached factorization.
pub struct DenseAltDiff {
    /// The registered problem.
    pub qp: Qp,
    /// ADMM penalty ρ (fixed at registration: the cached factor is of
    /// H(ρ)).
    pub rho: f64,
    pub(crate) chol: Chol,
    /// Explicit H⁻¹. One extra n³ at registration, but the backward's
    /// (7a) becomes a single blocked gemm instead of d column-wise
    /// triangular-solve pairs — measured 2.3× faster on the n=128
    /// full-Jacobian training path (EXPERIMENTS.md §Perf).
    /// (pub(crate): `batch::BatchedAltDiff` shares the factorization
    /// instead of re-paying the registration n³.)
    pub(crate) hinv_cache: Mat,
    pub(crate) at: Mat, // Aᵀ cached (n,p)
    pub(crate) gt: Mat, // Gᵀ cached (n,m)
}

impl DenseAltDiff {
    /// Register: assemble and factor the (constant) Hessian.
    ///
    /// If H = P + ρAᵀA + ρGᵀG is only PSD (e.g. an LP: P = 0 with fewer
    /// than n independent constraint rows), a tiny ridge is added — the
    /// standard proximal regularization; the fixed point is perturbed by
    /// O(ridge) only.
    pub fn new(qp: Qp, rho: f64) -> Result<Self> {
        let mut h = qp.p.clone();
        h.symmetrize();
        h.axpy(rho, &linalg::ata(&qp.a));
        h.axpy(rho, &linalg::ata(&qp.g));
        let chol = match Chol::factor(&h) {
            Ok(c) => c,
            Err(_) => {
                let ridge = 1e-8 * (1.0 + h.fro() / h.rows as f64);
                for i in 0..h.rows {
                    h[(i, i)] += ridge;
                }
                Chol::factor(&h)?
            }
        };
        let at = qp.a.transpose();
        let gt = qp.g.transpose();
        let hinv_cache = chol.inverse();
        Ok(DenseAltDiff { qp, rho, chol, hinv_cache, at, gt })
    }

    /// Explicit H⁻¹ — also the artifact input for the compiled path.
    pub fn hinv(&self) -> Mat {
        self.hinv_cache.clone()
    }

    /// Solve + differentiate with per-request parameters θ = (q, b, h).
    /// Pass `None` to use the registered problem's own parameters.
    pub fn solve_with(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        opts: &Options,
    ) -> Solution {
        self.solve_from(q, b, h, None, opts)
    }

    /// [`Self::solve_with`] resuming the primal/dual alternation from a
    /// prior iterate triple instead of zero. The warm slack is derived
    /// from the triple via the (6) projection s = max(0, −ν/ρ −
    /// (Gx − h)) against the *requested* h, so a fixed-point triple
    /// reproduces its own slack exactly; `warm = None` is bit-identical
    /// to the cold [`Self::solve_with`].
    ///
    /// Warm starts compose with [`BackwardMode::None`] and
    /// [`BackwardMode::Adjoint`] at any tolerance, and with
    /// [`BackwardMode::Forward`] only at `tol = 0` (fixed-k): a warm
    /// primal converges before the cold Jacobian recursion does, so a
    /// tol-truncated forward-mode run would stop with the Jacobian
    /// still wrong (asserted; see DESIGN.md §5).
    pub fn solve_from(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
    ) -> Solution {
        self.solve_observed(q, b, h, warm, opts, None)
    }

    /// [`Self::solve_from`] with a per-iteration [`IterObserver`] hook
    /// (the single-problem form of
    /// [`BatchedAltDiff::solve_batch_observed`](crate::batch::BatchedAltDiff::solve_batch_observed)):
    /// the solve is element 0 of a batch of one, so the observer is
    /// consulted with `elem = 0`. KKT residuals are computed only when
    /// the observer claims the element; `observer = None` costs one
    /// branch per iteration and the returned solution is identical.
    pub fn solve_observed(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        warm: Option<&WarmStart>,
        opts: &Options,
        mut observer: Option<&mut dyn IterObserver>,
    ) -> Solution {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        // ρ is a registration-time property: the cached Cholesky factor is
        // of H(ρ). Per-solve overrides would silently desynchronize them.
        let rho = self.rho;
        let q = q.unwrap_or(&self.qp.q);
        let b = b.unwrap_or(&self.qp.b);
        let h = h.unwrap_or(&self.qp.h);

        let mut x = vec![0.0; n];
        let mut s = vec![0.0; m];
        let mut lam = vec![0.0; p];
        let mut nu = vec![0.0; m];
        if let Some(w) = warm {
            assert!(
                opts.backward.forward_param().is_none() || opts.tol == 0.0,
                "warm starts with forward-mode Jacobians require tol = 0 \
                 (fixed-k); use BackwardMode::None/Adjoint for truncated \
                 warm solves"
            );
            assert_eq!(w.dims(), (n, p, m), "warm-start dimensions");
            x.copy_from_slice(&w.x);
            lam.copy_from_slice(&w.lam);
            nu.copy_from_slice(&w.nu);
            let mut gx0 = vec![0.0; m];
            gemv_acc(&mut gx0, 1.0, &self.qp.g, &x);
            for i in 0..m {
                s[i] = (-nu[i] / rho - (gx0[i] - h[i])).max(0.0);
            }
        }

        // Jacobian state (eq. 7), present only in forward mode.
        let param = opts.backward.forward_param();
        let d = param.map(|pm| pm.dim(n, m, p));
        let mut jx = d.map(|d| Mat::zeros(n, d));
        let mut js = d.map(|d| Mat::zeros(m, d));
        let mut jl = d.map(|d| Mat::zeros(p, d));
        let mut jn = d.map(|d| Mat::zeros(m, d));
        // backward work buffers, allocated once per solve (not per iter)
        let mut work = d.map(|d| JacWork::new(n, m, p, d));

        let mut trace = Vec::new();
        let mut rhs = vec![0.0; n];
        let mut xprev = vec![0.0; n];
        let mut gx = vec![0.0; m];
        let mut ax = vec![0.0; p];
        let mut hms = vec![0.0; m];
        let mut iters = 0;
        let mut step_rel = f64::INFINITY;

        for k in 0..opts.max_iter {
            iters = k + 1;
            xprev.copy_from_slice(&x);

            // ---- forward (5a): H x = -q - Aᵀλ - Gᵀν + ρAᵀb + ρGᵀ(h-s)
            for i in 0..n {
                rhs[i] = -q[i];
            }
            gemv_t_acc(&mut rhs, -1.0, &self.qp.a, &lam);
            gemv_t_acc(&mut rhs, -1.0, &self.qp.g, &nu);
            gemv_t_acc(&mut rhs, rho, &self.qp.a, b);
            for i in 0..m {
                hms[i] = h[i] - s[i];
            }
            gemv_t_acc(&mut rhs, rho, &self.qp.g, &hms);
            x.copy_from_slice(&rhs);
            self.chol.solve_in_place(&mut x);

            // ---- (6): slack, (5c)/(5d): duals
            gx.iter_mut().for_each(|v| *v = 0.0);
            gemv_acc(&mut gx, 1.0, &self.qp.g, &x);
            for i in 0..m {
                s[i] = (-nu[i] / rho - (gx[i] - h[i])).max(0.0);
            }
            ax.iter_mut().for_each(|v| *v = 0.0);
            gemv_acc(&mut ax, 1.0, &self.qp.a, &x);
            for i in 0..p {
                lam[i] += rho * (ax[i] - b[i]);
            }
            for i in 0..m {
                nu[i] += rho * (gx[i] + s[i] - h[i]);
            }

            // ---- backward (7a)-(7d)
            if let (Some(jx), Some(js), Some(jl), Some(jn), Some(w)) = (
                jx.as_mut(),
                js.as_mut(),
                jl.as_mut(),
                jn.as_mut(),
                work.as_mut(),
            ) {
                self.jacobian_step(
                    param.unwrap(),
                    &s,
                    jx,
                    js,
                    jl,
                    jn,
                    w,
                    rho,
                );
            }

            // ---- truncation check (Algorithm 1 condition)
            let dx: f64 = x
                .iter()
                .zip(&xprev)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            // sampled-trace hook: ax/gx/s hold the k+1 iterate here
            if let Some(obs) = observer.as_deref_mut() {
                if obs.wants(0) {
                    let mut pr = 0.0;
                    for i in 0..p {
                        let v = ax[i] - b[i];
                        pr += v * v;
                    }
                    for i in 0..m {
                        let v = gx[i] + s[i] - h[i];
                        pr += v * v;
                    }
                    obs.on_iter(0, k, pr.sqrt(), rho * dx);
                }
            }
            step_rel = dx / norm2(&xprev).max(1.0);
            if opts.trace {
                trace.push(TraceEntry {
                    iter: k,
                    step_rel,
                    jac_norm: jx.as_ref().map(|j| j.fro()).unwrap_or(0.0),
                });
            }
            if step_rel < opts.tol {
                break;
            }
        }

        Solution { x, s, lam, nu, jacobian: jx, iters, step_rel, trace }
    }

    /// Convenience: registered parameters, default θ.
    pub fn solve(&self, opts: &Options) -> Solution {
        self.solve_with(None, None, None, opts)
    }

    /// One backward update (7a)-(7d). `s1` is the freshly updated slack;
    /// `w` is the per-solve workspace (no per-iteration heap traffic).
    #[allow(clippy::too_many_arguments)]
    fn jacobian_step(
        &self,
        param: Param,
        s1: &[f64],
        jx: &mut Mat,
        js: &mut Mat,
        jl: &mut Mat,
        jn: &mut Mat,
        w: &mut JacWork,
        rho: f64,
    ) {
        let n = self.qp.n();
        let d = jx.cols;

        // ∇_{x,θ}L = Aᵀ Jλ + Gᵀ Jν + ρGᵀ Js + const(θ)
        let lxt = &mut w.lxt;
        lxt.data.fill(0.0);
        gemm_acc(lxt, 1.0, &self.at, jl);
        gemm_acc(lxt, 1.0, &self.gt, jn);
        gemm_acc(lxt, rho, &self.gt, js);
        match param {
            Param::Q => {
                // + I (from ∂q)
                for i in 0..n.min(d) {
                    lxt[(i, i)] += 1.0;
                }
            }
            Param::B => {
                // - ρAᵀ
                lxt.axpy(-rho, &self.at);
            }
            Param::H => {
                // - ρGᵀ  (from ρGᵀ(s-h) term)
                lxt.axpy(-rho, &self.gt);
            }
        }
        // (7a): Jx = -H⁻¹ lxt — one blocked gemm against the cached
        // explicit inverse (Appendix B.1: H⁻¹ is constant for QP layers).
        w.newjx.data.fill(0.0);
        gemm_acc(&mut w.newjx, -1.0, &self.hinv_cache, &w.lxt);
        std::mem::swap(jx, &mut w.newjx);

        // (7b): Js = sgn(s⁺) ⊙ (-(1/ρ))(Jν + ρ(G Jx - ∂h/∂θ))
        let gjx = &mut w.gjx;
        gjx.data.fill(0.0);
        gemm_acc(gjx, 1.0, &self.qp.g, jx);
        if param == Param::H {
            for i in 0..gjx.rows.min(d) {
                gjx[(i, i)] -= 1.0;
            }
        }
        for i in 0..js.rows {
            let gate = if s1[i] > 0.0 { 1.0 } else { 0.0 };
            for c in 0..d {
                js[(i, c)] = gate
                    * (-(1.0 / rho))
                    * (jn[(i, c)] + rho * gjx[(i, c)]);
            }
        }

        // (7c): Jλ += ρ(A Jx - ∂b/∂θ)
        w.ajx.data.fill(0.0);
        gemm_acc(&mut w.ajx, 1.0, &self.qp.a, jx);
        jl.axpy(rho, &w.ajx);
        if param == Param::B {
            for i in 0..jl.rows.min(d) {
                jl[(i, i)] -= rho;
            }
        }

        // (7d): Jν += ρ(G Jx + Js - ∂h/∂θ)  [gjx already holds GJx - ∂h]
        jn.axpy(rho, &w.gjx);
        jn.axpy(rho, js);
    }

    /// Reverse-mode backward against an already-solved forward pass:
    /// given the final slack `s*` (whose sign pattern gates (7b)) and the
    /// incoming gradient `v = dL/dx*`, iterate the transposed recursion
    ///
    ///   z  = −H⁻¹(−Gᵀ(σ ⊙ wₛ) + ρAᵀw_λ + ρGᵀ((1−σ) ⊙ w_ν))
    ///   wₛ ← ρGz + ρGt,   w_λ ← w_λ + Az + At,
    ///   w_ν ← (1−σ) ⊙ w_ν + Gz − (σ ⊙ wₛ)/ρ + Gt,   t = −H⁻¹v
    ///
    /// to its fixed point, then project out vᵀ∂x*/∂θ for every θ at once.
    /// Cost per iteration: one Cholesky solve + four gemvs — independent
    /// of the parameter dimension d. Truncation mirrors Algorithm 1 on
    /// the adjoint iterate z (`opts.tol`; `tol = 0` runs exactly
    /// `opts.max_iter` iterations, the serving contract).
    pub fn vjp(&self, slack: &[f64], v: &[f64], opts: &Options) -> Vjp {
        self.vjp_from(slack, v, None, opts).0
    }

    /// [`Self::vjp`] resuming the transposed recursion from a prior
    /// adjoint state and returning the final state for the next caller
    /// to reuse. The recursion w ← Mᵀw + V converges to its fixed point
    /// from any start, so a seed harvested from a previous backward (at
    /// a nearby v and slack pattern) cuts the iteration count the same
    /// way a primal warm start cuts the forward pass; `warm = None` is
    /// bit-identical to the cold [`Self::vjp`].
    pub fn vjp_from(
        &self,
        slack: &[f64],
        v: &[f64],
        warm: Option<&AdjointSeed>,
        opts: &Options,
    ) -> (Vjp, AdjointSeed) {
        let n = self.qp.n();
        let m = self.qp.m_ineq();
        let p = self.qp.p_eq();
        let rho = self.rho;
        assert_eq!(slack.len(), m, "slack dimension");
        assert_eq!(v.len(), n, "v dimension");
        let gate: Vec<f64> =
            slack.iter().map(|&s| if s > 0.0 { 1.0 } else { 0.0 }).collect();

        // t = −H⁻¹ v, and the parameter-independent seeds
        // (vs, vl, vn) = (ρGt, At, Gt).
        let mut t = v.to_vec();
        self.chol.solve_in_place(&mut t);
        t.iter_mut().for_each(|ti| *ti = -*ti);
        let mut vn = vec![0.0; m];
        gemv_acc(&mut vn, 1.0, &self.qp.g, &t);
        let mut vl = vec![0.0; p];
        gemv_acc(&mut vl, 1.0, &self.qp.a, &t);

        // W₁ = V (first application of the series Σ (Mᵀ)ʲ V), unless a
        // prior adjoint state resumes the series further along
        let mut ws: Vec<f64> = vn.iter().map(|&g| rho * g).collect();
        let mut wl = vl.clone();
        let mut wn = vn.clone();

        let mut z = vec![0.0; n];
        let seeded = warm.is_some();
        if let Some(seed) = warm {
            assert_eq!(seed.dims(), (n, p, m), "adjoint-seed dimensions");
            ws.copy_from_slice(&seed.ws);
            wl.copy_from_slice(&seed.wl);
            wn.copy_from_slice(&seed.wn);
            z.copy_from_slice(&seed.z);
        }
        let mut zprev = vec![0.0; n];
        let mut rhs = vec![0.0; n];
        let mut dws = vec![0.0; m];
        let mut ewn = vec![0.0; m];
        let mut gz = vec![0.0; m];
        let mut az = vec![0.0; p];
        let mut iters = 1;
        let mut step_rel = f64::INFINITY;

        let zstep = |rhs: &mut Vec<f64>,
                     z: &mut Vec<f64>,
                     dws: &mut Vec<f64>,
                     ewn: &mut Vec<f64>,
                     ws: &[f64],
                     wl: &[f64],
                     wn: &[f64]| {
            for i in 0..m {
                dws[i] = gate[i] * ws[i];
                ewn[i] = (1.0 - gate[i]) * wn[i];
            }
            rhs.iter_mut().for_each(|r| *r = 0.0);
            gemv_t_acc(rhs, 1.0, &self.qp.g, dws);
            gemv_t_acc(rhs, -rho, &self.qp.a, wl);
            gemv_t_acc(rhs, -rho, &self.qp.g, ewn);
            z.copy_from_slice(rhs);
            self.chol.solve_in_place(z);
        };

        for k in 1..opts.max_iter {
            zprev.copy_from_slice(&z);
            zstep(
                &mut rhs, &mut z, &mut dws, &mut ewn, &ws, &wl, &wn,
            );
            // W ← MᵀW + V
            gz.iter_mut().for_each(|g| *g = 0.0);
            gemv_acc(&mut gz, 1.0, &self.qp.g, &z);
            az.iter_mut().for_each(|a| *a = 0.0);
            gemv_acc(&mut az, 1.0, &self.qp.a, &z);
            for i in 0..m {
                // order matters: wn reads the OLD ws
                wn[i] = (1.0 - gate[i]) * wn[i] + gz[i]
                    - gate[i] * ws[i] / rho
                    + vn[i];
                ws[i] = rho * gz[i] + rho * vn[i];
            }
            for i in 0..p {
                wl[i] += az[i] + vl[i];
            }
            iters = k + 1;
            let dz: f64 = z
                .iter()
                .zip(&zprev)
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>()
                .sqrt();
            step_rel = dz / norm2(&zprev).max(1.0);
            // a seeded first iteration reproduces the harvested z
            // exactly (z₁ = zstep(w₀) = seed.z under unchanged gates),
            // so its zero step says nothing about convergence for the
            // NEW v — require one genuine step before trusting it
            if step_rel < opts.tol && (k > 1 || !seeded) {
                break;
            }
        }
        // final z at the converged adjoint state
        zstep(&mut rhs, &mut z, &mut dws, &mut ewn, &ws, &wl, &wn);

        // the reusable adjoint state, harvested before the projection
        // consumes the w's
        let seed_out = AdjointSeed {
            z: z.clone(),
            ws: ws.clone(),
            wl: wl.clone(),
            wn: wn.clone(),
        };

        // project: grad_q = z+t; grad_b = −ρA(z+t) − ρw_λ;
        // grad_h = −ρG(z+t) + σ⊙wₛ − ρ(1−σ)⊙w_ν.
        let zt: Vec<f64> =
            z.iter().zip(&t).map(|(zi, ti)| zi + ti).collect();
        let mut grad_b: Vec<f64> = wl.iter().map(|&w| -rho * w).collect();
        gemv_acc(&mut grad_b, -rho, &self.qp.a, &zt);
        let mut grad_h: Vec<f64> = (0..m)
            .map(|i| gate[i] * ws[i] - rho * (1.0 - gate[i]) * wn[i])
            .collect();
        gemv_acc(&mut grad_h, -rho, &self.qp.g, &zt);
        (Vjp { grad_q: zt, grad_b, grad_h, iters, step_rel }, seed_out)
    }

    /// Forward solve + reverse-mode backward in one call: solves the QP
    /// (no Jacobian state), then runs the adjoint iteration for
    /// `v = dL/dx*`. This is the training entry point — O(d)-free.
    ///
    /// ```
    /// use altdiff::altdiff::{DenseAltDiff, Options};
    /// use altdiff::prob::dense_qp;
    ///
    /// let layer = DenseAltDiff::new(dense_qp(8, 4, 2, 3), 1.0).unwrap();
    /// let v = vec![1.0; 8]; // dL/dx*
    /// let out = layer.solve_vjp(None, None, None, &v, &Options::with_tol(1e-9));
    /// assert_eq!(out.vjp.grad_q.len(), 8); // vᵀ∂x*/∂q
    /// assert_eq!(out.vjp.grad_b.len(), 2); // vᵀ∂x*/∂b — same backward
    /// assert!(out.solution.jacobian.is_none()); // never materialized
    /// ```
    pub fn solve_vjp(
        &self,
        q: Option<&[f64]>,
        b: Option<&[f64]>,
        h: Option<&[f64]>,
        v: &[f64],
        opts: &Options,
    ) -> VjpSolution {
        let fopts =
            Options { backward: BackwardMode::None, ..opts.clone() };
        let solution = self.solve_with(q, b, h, &fopts);
        let vjp = self.vjp(&solution.s, v, opts);
        VjpSolution { solution, vjp }
    }
}

/// Forward-mode backward work buffers, allocated once per solve and
/// reused across iterations (hoisted out of the hot loop).
struct JacWork {
    lxt: Mat,
    newjx: Mat,
    gjx: Mat,
    ajx: Mat,
}

impl JacWork {
    fn new(n: usize, m: usize, p: usize, d: usize) -> Self {
        JacWork {
            lxt: Mat::zeros(n, d),
            newjx: Mat::zeros(n, d),
            gjx: Mat::zeros(m, d),
            ajx: Mat::zeros(p, d),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prob::dense_qp;

    fn solver(n: usize, m: usize, p: usize, seed: u64) -> DenseAltDiff {
        DenseAltDiff::new(dense_qp(n, m, p, seed), 1.0).unwrap()
    }

    #[test]
    fn forward_reaches_kkt_point() {
        let s = solver(20, 10, 4, 1);
        let sol = s.solve(&Options {
            tol: 1e-9,
            max_iter: 20_000,
            backward: BackwardMode::None,
            ..Default::default()
        });
        let r = s.qp.kkt_residual(&sol.x, &sol.lam, &sol.nu);
        assert!(r < 1e-5, "kkt residual {r} after {} iters", sol.iters);
        assert!(sol.nu.iter().all(|&v| v >= -1e-8), "dual feasibility");
        assert!(sol.s.iter().all(|&v| v >= 0.0), "slack nonnegative");
    }

    #[test]
    fn jacobian_b_matches_finite_difference() {
        let s = solver(12, 6, 3, 2);
        let opts = Options {
            tol: 1e-10,
            max_iter: 30_000,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        };
        let sol = s.solve(&opts);
        let j = sol.jacobian.as_ref().unwrap();
        let eps = 1e-5;
        let fopts = Options { backward: BackwardMode::None, ..opts.clone() };
        for c in 0..3 {
            let mut bp = s.qp.b.clone();
            bp[c] += eps;
            let mut bm = s.qp.b.clone();
            bm[c] -= eps;
            let xp = s.solve_with(None, Some(&bp), None, &fopts).x;
            let xm = s.solve_with(None, Some(&bm), None, &fopts).x;
            for i in 0..12 {
                let fd = (xp[i] - xm[i]) / (2.0 * eps);
                assert!(
                    (j[(i, c)] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                    "J[{i},{c}]={} fd={fd}",
                    j[(i, c)]
                );
            }
        }
    }

    #[test]
    fn jacobian_q_matches_finite_difference() {
        let s = solver(10, 5, 2, 3);
        let opts = Options {
            tol: 1e-10,
            max_iter: 30_000,
            backward: BackwardMode::Forward(Param::Q),
            ..Default::default()
        };
        let sol = s.solve(&opts);
        let j = sol.jacobian.as_ref().unwrap();
        let eps = 1e-5;
        let fopts = Options { backward: BackwardMode::None, ..opts.clone() };
        for c in [0usize, 4, 9] {
            let mut qp_ = s.qp.q.clone();
            qp_[c] += eps;
            let mut qm = s.qp.q.clone();
            qm[c] -= eps;
            let xp = s.solve_with(Some(&qp_), None, None, &fopts).x;
            let xm = s.solve_with(Some(&qm), None, None, &fopts).x;
            for i in 0..10 {
                let fd = (xp[i] - xm[i]) / (2.0 * eps);
                assert!(
                    (j[(i, c)] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                    "J[{i},{c}]={} fd={fd}",
                    j[(i, c)]
                );
            }
        }
    }

    #[test]
    fn jacobian_h_matches_finite_difference() {
        let s = solver(10, 5, 2, 4);
        let opts = Options {
            tol: 1e-10,
            max_iter: 30_000,
            backward: BackwardMode::Forward(Param::H),
            ..Default::default()
        };
        let sol = s.solve(&opts);
        let j = sol.jacobian.as_ref().unwrap();
        let eps = 1e-5;
        let fopts = Options { backward: BackwardMode::None, ..opts.clone() };
        for c in 0..5 {
            let mut hp = s.qp.h.clone();
            hp[c] += eps;
            let mut hm = s.qp.h.clone();
            hm[c] -= eps;
            let xp = s.solve_with(None, None, Some(&hp), &fopts).x;
            let xm = s.solve_with(None, None, Some(&hm), &fopts).x;
            for i in 0..10 {
                let fd = (xp[i] - xm[i]) / (2.0 * eps);
                assert!(
                    (j[(i, c)] - fd).abs() < 1e-3 * (1.0 + fd.abs()),
                    "J[{i},{c}]={} fd={fd}",
                    j[(i, c)]
                );
            }
        }
    }

    #[test]
    fn truncation_monotone_jacobian_error() {
        // Thm 4.3: looser tolerance → larger (but bounded) Jacobian error.
        let s = solver(16, 8, 3, 5);
        let exact = s
            .solve(&Options {
                tol: 1e-12,
                max_iter: 50_000,
                ..Default::default()
            })
            .jacobian
            .unwrap();
        let mut errs = Vec::new();
        for tol in [1e-1, 1e-3, 1e-6] {
            let j = s
                .solve(&Options { tol, ..Default::default() })
                .jacobian
                .unwrap();
            errs.push(j.sub(&exact).fro());
        }
        assert!(errs[0] >= errs[1] && errs[1] >= errs[2], "{errs:?}");
        // Thm 4.3 is an order bound (constant C₁ depends on conditioning):
        // check a small *relative* error at the tight tolerance.
        assert!(errs[2] / exact.fro() < 1e-2, "{errs:?}");
    }

    #[test]
    fn trace_records_monotoneish_convergence() {
        let s = solver(12, 6, 2, 6);
        let sol = s.solve(&Options {
            tol: 1e-8,
            trace: true,
            ..Default::default()
        });
        assert_eq!(sol.trace.len(), sol.iters);
        let first = sol.trace.first().unwrap().step_rel;
        let last = sol.trace.last().unwrap().step_rel;
        assert!(last < first);
        assert!(last < 1e-8);
    }

    #[test]
    fn vjp_matches_explicit_product() {
        let s = solver(8, 4, 2, 7);
        let sol = s.solve(&Options::default());
        let g: Vec<f64> = (0..8).map(|i| (i as f64) - 3.5).collect();
        let v = sol.vjp(&g);
        let j = sol.jacobian.as_ref().unwrap();
        for c in 0..2 {
            let want: f64 = (0..8).map(|i| g[i] * j[(i, c)]).sum();
            assert!((v[c] - want).abs() < 1e-12);
        }
    }
}
