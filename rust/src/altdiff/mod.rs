//! The paper's contribution: Alt-Diff — alternating differentiation for
//! optimization layers (Algorithm 1).
//!
//! Forward: ADMM on the augmented Lagrangian (eq. 5). Backward: the same
//! loop propagates the Jacobians of every iterate w.r.t. a chosen
//! parameter (eq. 7) — no (n+n_c)-dimensional KKT factorization, ever.
//! Truncation (§4.3) is a first-class option: stop at tolerance ε and the
//! Jacobian error is bounded by C₁‖x_k − x*‖ (Thm 4.3).
//!
//! - [`dense`]: dense QP path; one Cholesky of H, O(kn²) thereafter.
//! - [`sparse`]: CSR path; matrix-free CG (or Sherman–Morrison for the
//!   structured sparsemax Hessian (2+2ρ)I + ρ11ᵀ — paper Table 3).
//! - [`newton`]: general convex objectives (entropy softmax layer) via an
//!   inner Newton solve for (5a), reusing its final Hessian for (7a).

pub mod dense;
pub mod newton;
pub mod sparse;

pub use dense::DenseAltDiff;
pub use newton::NewtonAltDiff;
pub use sparse::SparseAltDiff;

use crate::linalg::Mat;

/// Which layer parameter θ the Jacobian ∂x/∂θ is propagated against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Param {
    /// Linear objective coefficient q (d = n). The common case when the
    /// layer's input feeds the objective (OptNet MNIST layer, softmax y).
    Q,
    /// Equality right-hand side b (d = p). The paper's Fig. 1 case.
    B,
    /// Inequality right-hand side h (d = m).
    H,
}

impl Param {
    /// Number of Jacobian columns for a (n, m, p) problem.
    pub fn dim(&self, n: usize, m: usize, p: usize) -> usize {
        match self {
            Param::Q => n,
            Param::B => p,
            Param::H => m,
        }
    }
}

/// Solver options (shared by all Alt-Diff paths).
#[derive(Clone, Debug)]
pub struct Options {
    /// ADMM penalty ρ (paper uses 1.0 throughout; ablated in benches).
    pub rho: f64,
    /// Truncation threshold ε on ‖x_{k+1}−x_k‖/max(‖x_k‖,1).
    pub tol: f64,
    /// Hard iteration cap.
    pub max_iter: usize,
    /// Propagate ∂x/∂θ for this parameter (None = forward only).
    pub jacobian: Option<Param>,
    /// Record a per-iteration trace (Fig. 1).
    pub trace: bool,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            rho: 1.0,
            tol: 1e-3,
            max_iter: 5000,
            jacobian: Some(Param::B),
            trace: false,
        }
    }
}

impl Options {
    /// Defaults with Jacobian propagation disabled (forward solve only).
    pub fn forward_only() -> Self {
        Options { jacobian: None, ..Default::default() }
    }

    /// Defaults at the given truncation tolerance.
    pub fn with_tol(tol: f64) -> Self {
        Options { tol, ..Default::default() }
    }
}

/// Per-iteration trace entry (drives the Fig. 1 reproduction).
#[derive(Clone, Debug)]
pub struct TraceEntry {
    /// Iteration index (0-based).
    pub iter: usize,
    /// ‖x_{k+1} − x_k‖ / max(‖x_k‖, 1)
    pub step_rel: f64,
    /// Frobenius norm of the current Jacobian ∂x_k/∂θ.
    pub jac_norm: f64,
}

/// Solution + gradients of one optimization-layer evaluation.
#[derive(Clone, Debug)]
pub struct Solution {
    /// Primal minimizer x*.
    pub x: Vec<f64>,
    /// Slack s ≥ 0 for the inequalities.
    pub s: Vec<f64>,
    /// Equality duals λ.
    pub lam: Vec<f64>,
    /// Inequality duals ν.
    pub nu: Vec<f64>,
    /// ∂x/∂θ (n × dim(θ)) when requested.
    pub jacobian: Option<Mat>,
    /// Iterations actually run before the truncation criterion fired.
    pub iters: usize,
    /// Final relative step size (the truncation criterion value).
    pub step_rel: f64,
    /// Per-iteration trace when [`Options::trace`] was set.
    pub trace: Vec<TraceEntry>,
}

impl Solution {
    /// Vector-Jacobian product gᵀ(∂x/∂θ): the quantity backprop needs.
    pub fn vjp(&self, g: &[f64]) -> Vec<f64> {
        let j = self.jacobian.as_ref().expect("no jacobian tracked");
        crate::linalg::gemv_t(j, g)
    }
}
