//! The paper's contribution: Alt-Diff — alternating differentiation for
//! optimization layers (Algorithm 1).
//!
//! Forward: ADMM on the augmented Lagrangian (eq. 5). Backward: the same
//! loop propagates the Jacobians of every iterate w.r.t. a chosen
//! parameter (eq. 7) — no (n+n_c)-dimensional KKT factorization, ever.
//! Truncation (§4.3) is a first-class option: stop at tolerance ε and the
//! Jacobian error is bounded by C₁‖x_k − x*‖ (Thm 4.3).
//!
//! - [`dense`]: dense QP path; one Cholesky of H, O(kn²) thereafter.
//! - [`sparse`]: CSR path; matrix-free CG (or Sherman–Morrison for the
//!   structured sparsemax Hessian (2+2ρ)I + ρ11ᵀ — paper Table 3).
//! - [`newton`]: general convex objectives (entropy softmax layer) via an
//!   inner Newton solve for (5a), reusing its final Hessian for (7a).

pub mod dense;
pub mod newton;
pub mod sparse;

pub use dense::DenseAltDiff;
pub use newton::NewtonAltDiff;
pub use sparse::SparseAltDiff;

use crate::linalg::Mat;

/// How gradients are propagated through the solve.
///
/// Forward mode materializes the full (n × d) Jacobian ∂x/∂θ alongside
/// the ADMM iteration (eq. 7) — O(k·n²·d) work, the right choice when
/// the Jacobian itself is the product (serving, Fig. 1 traces). Adjoint
/// mode never forms the Jacobian: training only ever consumes a
/// vector-Jacobian product vᵀ∂x*/∂θ, and the transposed recursion
/// propagates a single (m+p+m) adjoint vector per backward — O(k·n²)
/// total, d-free — via [`DenseAltDiff::solve_vjp`] and friends.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackwardMode {
    /// Forward solve only: no gradient state of any kind.
    None,
    /// Forward-mode (eq. 7): materialize ∂x/∂θ for this parameter.
    Forward(Param),
    /// Reverse-mode: the solve itself carries no Jacobian state; pair
    /// with `solve_vjp`/`solve_batch_vjp`, which run the transposed
    /// recursion after the forward pass. Plain `solve`/`solve_batch`
    /// treat this like [`BackwardMode::None`].
    Adjoint,
}

impl BackwardMode {
    /// The forward-mode parameter, if this mode materializes a Jacobian.
    pub fn forward_param(&self) -> Option<Param> {
        match self {
            BackwardMode::Forward(p) => Some(*p),
            _ => None,
        }
    }
}

/// Which layer parameter θ the Jacobian ∂x/∂θ is propagated against.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Param {
    /// Linear objective coefficient q (d = n). The common case when the
    /// layer's input feeds the objective (OptNet MNIST layer, softmax y).
    Q,
    /// Equality right-hand side b (d = p). The paper's Fig. 1 case.
    B,
    /// Inequality right-hand side h (d = m).
    H,
}

impl Param {
    /// Number of Jacobian columns for a (n, m, p) problem.
    pub fn dim(&self, n: usize, m: usize, p: usize) -> usize {
        match self {
            Param::Q => n,
            Param::B => p,
            Param::H => m,
        }
    }
}

/// Solver options (shared by all Alt-Diff paths).
#[derive(Clone, Debug)]
pub struct Options {
    /// ADMM penalty ρ (paper uses 1.0 throughout; ablated in benches).
    pub rho: f64,
    /// Truncation threshold ε on ‖x_{k+1}−x_k‖/max(‖x_k‖,1).
    pub tol: f64,
    /// Hard iteration cap.
    pub max_iter: usize,
    /// Gradient propagation mode (see [`BackwardMode`]).
    pub backward: BackwardMode,
    /// Record a per-iteration trace (Fig. 1).
    pub trace: bool,
}

impl Default for Options {
    fn default() -> Self {
        Options {
            rho: 1.0,
            tol: 1e-3,
            max_iter: 5000,
            backward: BackwardMode::Forward(Param::B),
            trace: false,
        }
    }
}

impl Options {
    /// Defaults with gradient propagation disabled (forward solve only).
    pub fn forward_only() -> Self {
        Options { backward: BackwardMode::None, ..Default::default() }
    }

    /// Defaults at the given truncation tolerance.
    pub fn with_tol(tol: f64) -> Self {
        Options { tol, ..Default::default() }
    }

    /// Defaults in adjoint (reverse) mode — see [`BackwardMode::Adjoint`].
    pub fn adjoint() -> Self {
        Options { backward: BackwardMode::Adjoint, ..Default::default() }
    }
}

/// Per-iteration trace entry (drives the Fig. 1 reproduction).
#[derive(Clone, Debug)]
pub struct TraceEntry {
    /// Iteration index (0-based).
    pub iter: usize,
    /// ‖x_{k+1} − x_k‖ / max(‖x_k‖, 1)
    pub step_rel: f64,
    /// Frobenius norm of the current Jacobian ∂x_k/∂θ.
    pub jac_norm: f64,
}

/// Solution + gradients of one optimization-layer evaluation.
#[derive(Clone, Debug)]
pub struct Solution {
    /// Primal minimizer x*.
    pub x: Vec<f64>,
    /// Slack s ≥ 0 for the inequalities.
    pub s: Vec<f64>,
    /// Equality duals λ.
    pub lam: Vec<f64>,
    /// Inequality duals ν.
    pub nu: Vec<f64>,
    /// ∂x/∂θ (n × dim(θ)) when requested.
    pub jacobian: Option<Mat>,
    /// Iterations actually run before the truncation criterion fired.
    pub iters: usize,
    /// Final relative step size (the truncation criterion value).
    pub step_rel: f64,
    /// Per-iteration trace when [`Options::trace`] was set.
    pub trace: Vec<TraceEntry>,
}

impl Solution {
    /// Vector-Jacobian product gᵀ(∂x/∂θ): the quantity backprop needs.
    ///
    /// Requires a forward-mode solve ([`BackwardMode::Forward`]); in
    /// adjoint mode the same product comes out of
    /// [`DenseAltDiff::solve_vjp`] (and its sparse/batched siblings)
    /// without the Jacobian ever existing.
    pub fn vjp(&self, g: &[f64]) -> Vec<f64> {
        let j = self.jacobian.as_ref().expect("no jacobian tracked");
        crate::linalg::gemv_t(j, g)
    }
}

/// Result of one reverse-mode (adjoint) backward pass: the gradients of
/// L = vᵀx* with respect to every right-hand-side parameter at once.
///
/// One adjoint iteration is parameter-independent (the parameter only
/// enters the final projection), so a single backward yields all three
/// gradients for the price of one — unlike forward mode, which commits
/// to one [`Param`] up front.
#[derive(Clone, Debug)]
pub struct Vjp {
    /// vᵀ(∂x*/∂q), length n.
    pub grad_q: Vec<f64>,
    /// vᵀ(∂x*/∂b), length p.
    pub grad_b: Vec<f64>,
    /// vᵀ(∂x*/∂h), length m.
    pub grad_h: Vec<f64>,
    /// Adjoint iterations actually run before truncation fired.
    pub iters: usize,
    /// Final relative step of the adjoint iterate (truncation value).
    pub step_rel: f64,
}

impl Vjp {
    /// The gradient for one parameter (same selector forward mode uses).
    pub fn grad(&self, p: Param) -> &[f64] {
        match p {
            Param::Q => &self.grad_q,
            Param::B => &self.grad_b,
            Param::H => &self.grad_h,
        }
    }
}

/// Forward solution plus the adjoint backward result, as returned by the
/// `solve_vjp` entry points.
#[derive(Clone, Debug)]
pub struct VjpSolution {
    /// The forward solve (no Jacobian is ever materialized).
    pub solution: Solution,
    /// Gradients of vᵀx* w.r.t. q, b, and h.
    pub vjp: Vjp,
}
