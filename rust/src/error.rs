//! Error taxonomy for the whole stack.

use thiserror::Error;

#[derive(Error, Debug)]
pub enum AltDiffError {
    #[error("matrix is not SPD: pivot {pivot} has value {value}")]
    NotSpd { pivot: usize, value: f64 },

    #[error("singular matrix at pivot {pivot}")]
    Singular { pivot: usize },

    #[error("solver did not converge: {iters} iterations, residual {residual}")]
    NoConvergence { iters: usize, residual: f64 },

    #[error("problem is infeasible or unbounded: {0}")]
    Infeasible(String),

    #[error("dimension mismatch: {0}")]
    DimMismatch(String),

    #[error("artifact registry error: {0}")]
    Registry(String),

    #[error("runtime (PJRT) error: {0}")]
    Runtime(String),

    #[error("coordinator error: {0}")]
    Coordinator(String),

    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

pub type Result<T> = std::result::Result<T, AltDiffError>;
