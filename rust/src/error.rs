//! Error taxonomy for the whole stack.
//!
//! Hand-rolled `Display`/`Error` impls: the offline environment has no
//! `thiserror`, and the taxonomy is small enough that the derive buys
//! nothing but a dependency.

use std::fmt;

/// Everything that can go wrong across registration, solving, artifact
/// loading, and serving.
#[derive(Debug)]
pub enum AltDiffError {
    /// A Cholesky factorization (or CG curvature check) found the
    /// Hessian not symmetric positive definite.
    NotSpd {
        /// Pivot (or iteration) at which definiteness failed.
        pivot: usize,
        /// The offending pivot/curvature value.
        value: f64,
    },

    /// A pivoted LU hit an (effectively) zero pivot.
    Singular {
        /// Pivot index at which elimination broke down.
        pivot: usize,
    },

    /// An iterative solver exhausted its budget above tolerance.
    NoConvergence {
        /// Iterations actually run.
        iters: usize,
        /// Final (relative) residual.
        residual: f64,
    },

    /// The problem is infeasible or unbounded.
    Infeasible(String),

    /// Inputs have inconsistent dimensions.
    DimMismatch(String),

    /// The artifact registry/manifest is missing or malformed.
    Registry(String),

    /// The PJRT runtime failed (or is unavailable in this build).
    Runtime(String),

    /// A coordinator-level failure (routing, channels, shutdown).
    Coordinator(String),

    /// A wire-protocol violation (bad magic/version, oversized or
    /// truncated frame, malformed payload). Decoders return this —
    /// they never panic or over-allocate on hostile input.
    Protocol(String),

    /// An underlying I/O error.
    Io(std::io::Error),
}

impl fmt::Display for AltDiffError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AltDiffError::NotSpd { pivot, value } => write!(
                f,
                "matrix is not SPD: pivot {pivot} has value {value}"
            ),
            AltDiffError::Singular { pivot } => {
                write!(f, "singular matrix at pivot {pivot}")
            }
            AltDiffError::NoConvergence { iters, residual } => write!(
                f,
                "solver did not converge: {iters} iterations, residual \
                 {residual}"
            ),
            AltDiffError::Infeasible(s) => {
                write!(f, "problem is infeasible or unbounded: {s}")
            }
            AltDiffError::DimMismatch(s) => {
                write!(f, "dimension mismatch: {s}")
            }
            AltDiffError::Registry(s) => {
                write!(f, "artifact registry error: {s}")
            }
            AltDiffError::Runtime(s) => {
                write!(f, "runtime (PJRT) error: {s}")
            }
            AltDiffError::Coordinator(s) => {
                write!(f, "coordinator error: {s}")
            }
            AltDiffError::Protocol(s) => {
                write!(f, "wire protocol error: {s}")
            }
            AltDiffError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for AltDiffError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            AltDiffError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for AltDiffError {
    fn from(e: std::io::Error) -> Self {
        AltDiffError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, AltDiffError>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_stable() {
        let e = AltDiffError::NotSpd { pivot: 3, value: -0.5 };
        assert_eq!(
            e.to_string(),
            "matrix is not SPD: pivot 3 has value -0.5"
        );
        assert!(AltDiffError::Registry("x".into())
            .to_string()
            .contains("artifact registry"));
    }

    #[test]
    fn io_error_converts_and_sources() {
        use std::error::Error;
        let io = std::io::Error::other("gone");
        let e: AltDiffError = io.into();
        assert!(e.to_string().contains("gone"));
        assert!(e.source().is_some());
    }
}
