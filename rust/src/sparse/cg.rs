//! Preconditioned Conjugate Gradient for SPD operators.
//!
//! The sparse Alt-Diff path solves H x = rhs with H = P + ρAᵀA + ρGᵀG
//! *applied matrix-free* (three spmv's per application) — never forming H.
//! This is the sparse analogue of CvxpyLayer's LSQR mode and what makes
//! the Table 4 sizes tractable. Jacobi (diagonal) preconditioning.

use super::csr::Csr;
use crate::error::AltDiffError;
use crate::linalg::dense::{axpy, dot, norm2};

/// An SPD linear operator y = Op(x).
pub trait SpdOp {
    /// y = Op(x).
    fn apply(&self, x: &[f64], y: &mut [f64]);
    /// Operator dimension n.
    fn dim(&self) -> usize;
    /// Diagonal (for Jacobi preconditioning); None → identity.
    fn diag(&self) -> Option<Vec<f64>> {
        None
    }
}

/// H = diag(pdiag) + rho AᵀA + rho GᵀG, matrix-free.
pub struct HessianOp<'a> {
    /// diag(P).
    pub pdiag: &'a [f64],
    /// Equality constraint matrix A (p, n).
    pub a: &'a Csr,
    /// Inequality constraint matrix G (m, n).
    pub g: &'a Csr,
    /// ADMM penalty ρ.
    pub rho: f64,
    /// scratch for A x / G x (len = max(a.rows, g.rows))
    scratch: std::cell::RefCell<Vec<f64>>,
}

impl<'a> HessianOp<'a> {
    /// Assemble the operator over borrowed problem parts.
    pub fn new(pdiag: &'a [f64], a: &'a Csr, g: &'a Csr, rho: f64) -> Self {
        let cap = a.rows.max(g.rows);
        HessianOp { pdiag, a, g, rho, scratch: vec![0.0; cap].into() }
    }
}

impl<'a> SpdOp for HessianOp<'a> {
    fn dim(&self) -> usize {
        self.pdiag.len()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        for (yi, (xi, di)) in y.iter_mut().zip(x.iter().zip(self.pdiag)) {
            *yi = di * xi;
        }
        let mut t = self.scratch.borrow_mut();
        // rho Aᵀ(A x)
        let ta = &mut t[..self.a.rows];
        ta.iter_mut().for_each(|v| *v = 0.0);
        self.a.spmv_acc(ta, 1.0, x);
        self.a.spmv_t_acc(y, self.rho, ta);
        // rho Gᵀ(G x)
        let tg = &mut t[..self.g.rows];
        tg.iter_mut().for_each(|v| *v = 0.0);
        self.g.spmv_acc(tg, 1.0, x);
        self.g.spmv_t_acc(y, self.rho, tg);
    }

    fn diag(&self) -> Option<Vec<f64>> {
        let mut d = self.pdiag.to_vec();
        for (di, ai) in d.iter_mut().zip(self.a.ata_diag()) {
            *di += self.rho * ai;
        }
        for (di, gi) in d.iter_mut().zip(self.g.ata_diag()) {
            *di += self.rho * gi;
        }
        Some(d)
    }
}

/// CG outcome.
#[derive(Debug, Clone, Copy)]
pub struct CgInfo {
    /// Iterations run before the criterion fired.
    pub iters: usize,
    /// Final relative residual.
    pub residual: f64,
}

/// Solve Op x = b to relative tolerance `tol`; x is in/out (warm start).
pub fn cg<O: SpdOp>(
    op: &O,
    b: &[f64],
    x: &mut [f64],
    tol: f64,
    max_iter: usize,
) -> Result<CgInfo, AltDiffError> {
    let n = op.dim();
    debug_assert_eq!(b.len(), n);
    debug_assert_eq!(x.len(), n);
    let bnorm = norm2(b).max(1e-30);
    let minv: Vec<f64> = match op.diag() {
        Some(d) => d.iter().map(|&v| 1.0 / v.max(1e-30)).collect(),
        None => vec![1.0; n],
    };
    let mut r = vec![0.0; n];
    op.apply(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut z: Vec<f64> = r.iter().zip(&minv).map(|(ri, mi)| ri * mi).collect();
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut ap = vec![0.0; n];
    for it in 0..max_iter {
        let rn = norm2(&r);
        if rn / bnorm < tol {
            return Ok(CgInfo { iters: it, residual: rn / bnorm });
        }
        op.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            return Err(AltDiffError::NotSpd { pivot: it, value: pap });
        }
        let alpha = rz / pap;
        axpy(x, alpha, &p);
        axpy(&mut r, -alpha, &ap);
        for i in 0..n {
            z[i] = r[i] * minv[i];
        }
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    let rn = norm2(&r) / bnorm;
    if rn < tol * 10.0 {
        // close enough — callers treat as converged-with-warning
        return Ok(CgInfo { iters: max_iter, residual: rn });
    }
    Err(AltDiffError::NoConvergence { iters: max_iter, residual: rn })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    struct DenseOp {
        m: crate::linalg::Mat,
    }
    impl SpdOp for DenseOp {
        fn dim(&self) -> usize {
            self.m.rows
        }
        fn apply(&self, x: &[f64], y: &mut [f64]) {
            y.iter_mut().for_each(|v| *v = 0.0);
            crate::linalg::gemv_acc(y, 1.0, &self.m, x);
        }
        fn diag(&self) -> Option<Vec<f64>> {
            Some((0..self.m.rows).map(|i| self.m[(i, i)]).collect())
        }
    }

    #[test]
    fn cg_solves_dense_spd() {
        let mut rng = Pcg64::new(1);
        let n = 30;
        let raw = crate::linalg::Mat::from_vec(n, n, rng.normal_vec(n * n));
        let mut spd = crate::linalg::ata(&raw);
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        let b = rng.normal_vec(n);
        let op = DenseOp { m: spd.clone() };
        let mut x = vec![0.0; n];
        let info = cg(&op, &b, &mut x, 1e-10, 500).unwrap();
        assert!(info.residual < 1e-9);
        let ax = crate::linalg::gemv(&spd, &x);
        for i in 0..n {
            assert!((ax[i] - b[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn hessian_op_matches_dense_assembly() {
        let mut rng = Pcg64::new(2);
        let (n, m, p) = (12, 8, 4);
        let adense =
            crate::linalg::Mat::from_vec(p, n, rng.normal_vec(p * n));
        let gdense =
            crate::linalg::Mat::from_vec(m, n, rng.normal_vec(m * n));
        let a = Csr::from_dense(&adense);
        let g = Csr::from_dense(&gdense);
        let pdiag = vec![2.0; n];
        let rho = 1.5;
        let op = HessianOp::new(&pdiag, &a, &g, rho);
        // dense H
        let mut h = crate::linalg::Mat::diag(&pdiag);
        h.axpy(rho, &crate::linalg::ata(&adense));
        h.axpy(rho, &crate::linalg::ata(&gdense));
        let x = rng.normal_vec(n);
        let mut y = vec![0.0; n];
        op.apply(&x, &mut y);
        let want = crate::linalg::gemv(&h, &x);
        for i in 0..n {
            assert!((y[i] - want[i]).abs() < 1e-10);
        }
        // diag matches too
        let d = op.diag().unwrap();
        for i in 0..n {
            assert!((d[i] - h[(i, i)]).abs() < 1e-10);
        }
    }

    #[test]
    fn cg_warm_start_converges_faster() {
        let mut rng = Pcg64::new(3);
        let n = 40;
        let raw = crate::linalg::Mat::from_vec(n, n, rng.normal_vec(n * n));
        let mut spd = crate::linalg::ata(&raw);
        for i in 0..n {
            spd[(i, i)] += n as f64;
        }
        let op = DenseOp { m: spd };
        let b = rng.normal_vec(n);
        let mut cold = vec![0.0; n];
        let it_cold = cg(&op, &b, &mut cold, 1e-10, 500).unwrap().iters;
        let mut warm = cold.clone(); // exact solution as warm start
        let it_warm = cg(&op, &b, &mut warm, 1e-10, 500).unwrap().iters;
        assert!(it_warm <= 1);
        assert!(it_cold > it_warm);
    }
}
