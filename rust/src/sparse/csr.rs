//! Compressed Sparse Row matrices.
//!
//! The paper's Table 4 regime (constrained sparsemax, n up to 20k) is all
//! about structure: A = 1ᵀ, G = [−I; I], P = 2I. Generic dense algebra
//! would be O(n²) per matvec where O(nnz) suffices; this module provides
//! the CSR type and the kernels the sparse Alt-Diff path uses.

use crate::linalg::Mat;

/// CSR sparse matrix (f64).
#[derive(Clone, Debug)]
pub struct Csr {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns.
    pub cols: usize,
    /// Row pointers: row i's nonzeros live at `indptr[i]..indptr[i+1]`.
    pub indptr: Vec<usize>,
    /// Column index of each nonzero.
    pub indices: Vec<usize>,
    /// Value of each nonzero.
    pub values: Vec<f64>,
}

impl Csr {
    /// Build from (row, col, value) triplets (duplicates summed).
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(usize, usize, f64)],
    ) -> Csr {
        let mut sorted: Vec<_> = triplets.to_vec();
        sorted.sort_by_key(|&(r, c, _)| (r, c));
        let mut indptr = vec![0usize; rows + 1];
        let mut indices = Vec::with_capacity(sorted.len());
        let mut values: Vec<f64> = Vec::with_capacity(sorted.len());
        let mut last: Option<(usize, usize)> = None;
        for &(r, c, v) in &sorted {
            assert!(r < rows && c < cols, "triplet out of bounds");
            if last == Some((r, c)) {
                // duplicates are adjacent after the sort → merge
                *values.last_mut().unwrap() += v;
            } else {
                indices.push(c);
                values.push(v);
                indptr[r + 1] = indices.len();
                last = Some((r, c));
            }
        }
        // make indptr cumulative-max (rows with no entries)
        for r in 1..=rows {
            if indptr[r] < indptr[r - 1] {
                indptr[r] = indptr[r - 1];
            }
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Identity.
    pub fn eye(n: usize) -> Csr {
        Csr {
            rows: n,
            cols: n,
            indptr: (0..=n).collect(),
            indices: (0..n).collect(),
            values: vec![1.0; n],
        }
    }

    /// Dense → CSR (drop zeros).
    pub fn from_dense(m: &Mat) -> Csr {
        let mut t = Vec::new();
        for i in 0..m.rows {
            for j in 0..m.cols {
                let v = m[(i, j)];
                if v != 0.0 {
                    t.push((i, j, v));
                }
            }
        }
        Csr::from_triplets(m.rows, m.cols, &t)
    }

    /// CSR → dense.
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for k in self.indptr[i]..self.indptr[i + 1] {
                m[(i, self.indices[k])] += self.values[k];
            }
        }
        m
    }

    /// Number of stored nonzeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// y = A x.
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.spmv_acc(&mut y, 1.0, x);
        y
    }

    /// y += alpha * A x.
    pub fn spmv_acc(&self, y: &mut [f64], alpha: f64, x: &[f64]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let mut s = 0.0;
            for k in self.indptr[i]..self.indptr[i + 1] {
                s += self.values[k] * x[self.indices[k]];
            }
            y[i] += alpha * s;
        }
    }

    /// y = Aᵀ x (no transpose materialization).
    pub fn spmv_t(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.cols];
        self.spmv_t_acc(&mut y, 1.0, x);
        y
    }

    /// y += alpha * Aᵀ x.
    pub fn spmv_t_acc(&self, y: &mut [f64], alpha: f64, x: &[f64]) {
        debug_assert_eq!(x.len(), self.rows);
        debug_assert_eq!(y.len(), self.cols);
        for i in 0..self.rows {
            let s = alpha * x[i];
            if s == 0.0 {
                continue;
            }
            for k in self.indptr[i]..self.indptr[i + 1] {
                y[self.indices[k]] += s * self.values[k];
            }
        }
    }

    /// Y += alpha · A X over the given column ranges, where X is a
    /// (cols, w) and Y a (rows, w) element-major block: column `e` of
    /// each block belongs to batch element `e`, so one CSR traversal
    /// serves the whole batch (the index decode is amortized across a
    /// contiguous row of `w` element lanes — the multi-RHS SpMM win).
    ///
    /// `ranges` are disjoint ascending `[c0, c1)` column ranges (see
    /// [`crate::batch::ActiveSet::col_ranges`]); columns outside them
    /// are left untouched and consume no flops. Per column, the
    /// accumulation order over a row's nonzeros matches [`Self::spmv`]
    /// exactly (row-local sum, then one scaled add into Y).
    pub fn spmm_acc(
        &self,
        y: &mut Mat,
        alpha: f64,
        x: &Mat,
        ranges: &[(usize, usize)],
    ) {
        let w = x.cols;
        debug_assert_eq!(x.rows, self.cols, "spmm x rows");
        debug_assert_eq!(y.rows, self.rows, "spmm y rows");
        debug_assert_eq!(y.cols, w, "spmm y cols");
        let mut acc = vec![0.0; w];
        for i in 0..self.rows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            if lo == hi {
                continue;
            }
            for &(c0, c1) in ranges {
                acc[c0..c1].fill(0.0);
            }
            for k in lo..hi {
                let v = self.values[k];
                let xr = x.row(self.indices[k]);
                for &(c0, c1) in ranges {
                    for c in c0..c1 {
                        acc[c] += v * xr[c];
                    }
                }
            }
            let yr = y.row_mut(i);
            for &(c0, c1) in ranges {
                for c in c0..c1 {
                    yr[c] += alpha * acc[c];
                }
            }
        }
    }

    /// Y += alpha · Aᵀ X over the given column ranges (multi-RHS
    /// companion of [`Self::spmv_t_acc`]; X is (rows, w), Y is
    /// (cols, w) element-major). Scatter order per output entry matches
    /// the single-vector kernel (ascending source row, ascending
    /// nonzero within the row).
    pub fn spmm_t_acc(
        &self,
        y: &mut Mat,
        alpha: f64,
        x: &Mat,
        ranges: &[(usize, usize)],
    ) {
        let w = x.cols;
        debug_assert_eq!(x.rows, self.rows, "spmm_t x rows");
        debug_assert_eq!(y.rows, self.cols, "spmm_t y rows");
        debug_assert_eq!(y.cols, w, "spmm_t y cols");
        for i in 0..self.rows {
            let xr = &x.data[i * w..(i + 1) * w];
            for k in self.indptr[i]..self.indptr[i + 1] {
                let av = alpha * self.values[k];
                if av == 0.0 {
                    continue;
                }
                let j = self.indices[k];
                let yr = &mut y.data[j * w..(j + 1) * w];
                for &(c0, c1) in ranges {
                    for c in c0..c1 {
                        yr[c] += av * xr[c];
                    }
                }
            }
        }
    }

    /// Explicit transpose.
    pub fn transpose(&self) -> Csr {
        let mut t = Vec::with_capacity(self.nnz());
        for i in 0..self.rows {
            for k in self.indptr[i]..self.indptr[i + 1] {
                t.push((self.indices[k], i, self.values[k]));
            }
        }
        Csr::from_triplets(self.cols, self.rows, &t)
    }

    /// AᵀA as CSR (via per-row outer products; fine for the thin/structured
    /// constraint matrices this repo generates).
    pub fn ata(&self) -> Csr {
        let mut t = Vec::new();
        for i in 0..self.rows {
            let lo = self.indptr[i];
            let hi = self.indptr[i + 1];
            for a in lo..hi {
                for b in lo..hi {
                    t.push((
                        self.indices[a],
                        self.indices[b],
                        self.values[a] * self.values[b],
                    ));
                }
            }
        }
        Csr::from_triplets(self.cols, self.cols, &t)
    }

    /// Diagonal of AᵀA (cheap preconditioner input).
    pub fn ata_diag(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.cols];
        for k in 0..self.nnz() {
            d[self.indices[k]] += self.values[k] * self.values[k];
        }
        d
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gemm, gemv};
    use crate::util::rng::Pcg64;

    fn random_sparse(r: usize, c: usize, density: f64, seed: u64) -> Csr {
        let mut rng = Pcg64::new(seed);
        let mut t = Vec::new();
        for i in 0..r {
            for j in 0..c {
                if rng.uniform() < density {
                    t.push((i, j, rng.normal()));
                }
            }
        }
        Csr::from_triplets(r, c, &t)
    }

    #[test]
    fn dense_roundtrip() {
        let s = random_sparse(13, 9, 0.3, 1);
        let d = s.to_dense();
        let s2 = Csr::from_dense(&d);
        assert!(s2.to_dense().max_abs_diff(&d) < 1e-15);
    }

    #[test]
    fn spmv_matches_dense() {
        let s = random_sparse(17, 11, 0.25, 2);
        let d = s.to_dense();
        let mut rng = Pcg64::new(3);
        let x = rng.normal_vec(11);
        let ys = s.spmv(&x);
        let yd = gemv(&d, &x);
        for i in 0..17 {
            assert!((ys[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn spmv_t_matches_dense() {
        let s = random_sparse(17, 11, 0.25, 4);
        let d = s.to_dense().transpose();
        let mut rng = Pcg64::new(5);
        let x = rng.normal_vec(17);
        let ys = s.spmv_t(&x);
        let yd = gemv(&d, &x);
        for i in 0..11 {
            assert!((ys[i] - yd[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn ata_matches_dense() {
        let s = random_sparse(9, 7, 0.4, 6);
        let d = s.to_dense();
        let want = gemm(&d.transpose(), &d);
        let got = s.ata().to_dense();
        assert!(got.max_abs_diff(&want) < 1e-12);
        let diag = s.ata_diag();
        for i in 0..7 {
            assert!((diag[i] - want[(i, i)]).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let s = random_sparse(8, 5, 0.5, 7);
        let tt = s.transpose().transpose();
        assert!(tt.to_dense().max_abs_diff(&s.to_dense()) < 1e-15);
    }

    #[test]
    fn spmm_matches_columnwise_spmv() {
        let s = random_sparse(11, 7, 0.3, 8);
        let mut rng = Pcg64::new(9);
        let w = 5;
        let x = Mat::from_vec(7, w, rng.normal_vec(7 * w));
        let mut y = Mat::zeros(11, w);
        s.spmm_acc(&mut y, 1.5, &x, &[(0, w)]);
        for c in 0..w {
            let xc = x.col(c);
            let yc = s.spmv(&xc);
            for i in 0..11 {
                assert!(
                    (y[(i, c)] - 1.5 * yc[i]).abs() < 1e-12,
                    "({i},{c})"
                );
            }
        }
    }

    #[test]
    fn spmm_t_matches_columnwise_spmv_t() {
        let s = random_sparse(11, 7, 0.3, 10);
        let mut rng = Pcg64::new(11);
        let w = 4;
        let x = Mat::from_vec(11, w, rng.normal_vec(11 * w));
        let mut y = Mat::zeros(7, w);
        s.spmm_t_acc(&mut y, -0.5, &x, &[(0, w)]);
        for c in 0..w {
            let xc = x.col(c);
            let yc = s.spmv_t(&xc);
            for i in 0..7 {
                assert!(
                    (y[(i, c)] + 0.5 * yc[i]).abs() < 1e-12,
                    "({i},{c})"
                );
            }
        }
    }

    #[test]
    fn spmm_masked_columns_untouched() {
        let s = random_sparse(6, 6, 0.5, 12);
        let mut rng = Pcg64::new(13);
        let x = Mat::from_vec(6, 4, rng.normal_vec(24));
        // poison the masked columns to prove they are skipped
        let mut y = Mat::zeros(6, 4);
        let mut yt = Mat::zeros(6, 4);
        for i in 0..6 {
            y[(i, 1)] = 42.0;
            yt[(i, 1)] = 42.0;
        }
        let ranges = [(0usize, 1usize), (2, 4)];
        s.spmm_acc(&mut y, 1.0, &x, &ranges);
        s.spmm_t_acc(&mut yt, 1.0, &x, &ranges);
        let mut full = Mat::zeros(6, 4);
        let mut fullt = Mat::zeros(6, 4);
        s.spmm_acc(&mut full, 1.0, &x, &[(0, 4)]);
        s.spmm_t_acc(&mut fullt, 1.0, &x, &[(0, 4)]);
        for i in 0..6 {
            for c in 0..4 {
                let (want, want_t) = if c == 1 {
                    (42.0, 42.0)
                } else {
                    (full[(i, c)], fullt[(i, c)])
                };
                assert_eq!(y[(i, c)], want, "spmm ({i},{c})");
                assert_eq!(yt[(i, c)], want_t, "spmm_t ({i},{c})");
            }
        }
    }

    #[test]
    fn duplicates_summed() {
        let s = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 0, 2.0)]);
        assert_eq!(s.to_dense()[(0, 0)], 3.0);
        assert_eq!(s.nnz(), 1);
    }

    #[test]
    fn empty_rows_ok() {
        let s = Csr::from_triplets(4, 3, &[(0, 1, 1.0), (3, 2, 2.0)]);
        let y = s.spmv(&[1.0, 1.0, 1.0]);
        assert_eq!(y, vec![1.0, 0.0, 0.0, 2.0]);
    }
}
