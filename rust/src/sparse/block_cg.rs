//! Blocked Jacobi-preconditioned Conjugate Gradient: solve `w` SPD
//! systems sharing one operator in a single sweep.
//!
//! The batched sparse Alt-Diff path needs H x_e = rhs_e for every batch
//! element e (and every Jacobian column) per ADMM iteration. Running
//! [`cg`](super::cg()) per column re-walks the CSR structure once per
//! system; the blocked variant applies the operator to an (n, w)
//! element-major block instead, so each index decode feeds `w`
//! contiguous lanes (multi-RHS SpMM). CG scalars (α, β, r·z) are per
//! column, and convergence is per column too: a converged column is
//! deactivated via the same [`ActiveSet`] mask the batch engine uses,
//! after which it is excluded from every operator application and
//! vector update — it stops consuming flops while the stragglers
//! finish.
//!
//! Per column the iteration is arithmetically the sequential
//! [`cg`](super::cg()) (same Jacobi preconditioner, same update order);
//! only the dot-product association differs (plain ascending-row
//! accumulation instead of the 4-way unrolled [`crate::linalg::dot`]),
//! an O(ulp) perturbation.

use super::csr::Csr;
use crate::batch::ActiveSet;
use crate::error::AltDiffError;
use crate::linalg::Mat;
use std::cell::RefCell;

/// An SPD operator applied to an (n, w) element-major block: column `e`
/// of X and Y is system `e`. The blocked analogue of [`super::SpdOp`].
pub trait SpdBlockOp {
    /// Y = Op(X), restricted to the given disjoint ascending column
    /// ranges; columns outside them must be left untouched.
    fn apply_block(&self, x: &Mat, y: &mut Mat, ranges: &[(usize, usize)]);
    /// Operator dimension n.
    fn dim(&self) -> usize;
    /// Diagonal (for Jacobi preconditioning); `None` → identity.
    fn diag(&self) -> Option<Vec<f64>> {
        None
    }
}

/// H = diag(pdiag) + ρAᵀA + ρGᵀG applied matrix-free to a block —
/// the blocked sibling of [`super::HessianOp`], built once per launch
/// with a fixed block width.
pub struct BlockHessianOp<'a> {
    /// diag(P).
    pub pdiag: &'a [f64],
    /// Equality constraint matrix A (p, n).
    pub a: &'a Csr,
    /// Inequality constraint matrix G (m, n).
    pub g: &'a Csr,
    /// ADMM penalty ρ.
    pub rho: f64,
    /// scratch for A X (a.rows, w)
    scratch_a: RefCell<Mat>,
    /// scratch for G X (g.rows, w)
    scratch_g: RefCell<Mat>,
}

impl<'a> BlockHessianOp<'a> {
    /// Build for blocks of `width` columns.
    pub fn new(
        pdiag: &'a [f64],
        a: &'a Csr,
        g: &'a Csr,
        rho: f64,
        width: usize,
    ) -> Self {
        BlockHessianOp {
            pdiag,
            a,
            g,
            rho,
            scratch_a: Mat::zeros(a.rows, width).into(),
            scratch_g: Mat::zeros(g.rows, width).into(),
        }
    }
}

impl<'a> SpdBlockOp for BlockHessianOp<'a> {
    fn dim(&self) -> usize {
        self.pdiag.len()
    }

    fn apply_block(&self, x: &Mat, y: &mut Mat, ranges: &[(usize, usize)]) {
        for (i, &d) in self.pdiag.iter().enumerate() {
            let xr = x.row(i);
            let yr = y.row_mut(i);
            for &(c0, c1) in ranges {
                for c in c0..c1 {
                    yr[c] = d * xr[c];
                }
            }
        }
        // ρ Aᵀ(A X)
        let mut ta = self.scratch_a.borrow_mut();
        zero_cols(&mut ta, ranges);
        self.a.spmm_acc(&mut ta, 1.0, x, ranges);
        self.a.spmm_t_acc(y, self.rho, &ta, ranges);
        // ρ Gᵀ(G X)
        let mut tg = self.scratch_g.borrow_mut();
        zero_cols(&mut tg, ranges);
        self.g.spmm_acc(&mut tg, 1.0, x, ranges);
        self.g.spmm_t_acc(y, self.rho, &tg, ranges);
    }

    fn diag(&self) -> Option<Vec<f64>> {
        let mut d = self.pdiag.to_vec();
        for (di, ai) in d.iter_mut().zip(self.a.ata_diag()) {
            *di += self.rho * ai;
        }
        for (di, gi) in d.iter_mut().zip(self.g.ata_diag()) {
            *di += self.rho * gi;
        }
        Some(d)
    }
}

/// Zero the given column ranges of a matrix.
pub(crate) fn zero_cols(m: &mut Mat, ranges: &[(usize, usize)]) {
    for i in 0..m.rows {
        let row = m.row_mut(i);
        for &(c0, c1) in ranges {
            row[c0..c1].fill(0.0);
        }
    }
}

/// Per-column dot products: out[c] = Σ_i a[i,c]·b[i,c] for columns in
/// `ranges` (ascending-row accumulation, one cache-friendly pass).
fn col_dots(a: &Mat, b: &Mat, ranges: &[(usize, usize)], out: &mut [f64]) {
    for &(c0, c1) in ranges {
        out[c0..c1].fill(0.0);
    }
    for i in 0..a.rows {
        let ar = a.row(i);
        let br = b.row(i);
        for &(c0, c1) in ranges {
            for c in c0..c1 {
                out[c] += ar[c] * br[c];
            }
        }
    }
}

/// Blocked-CG outcome, per column.
#[derive(Debug, Clone)]
pub struct BlockCgInfo {
    /// Iterations each column ran before its criterion fired.
    pub iters: Vec<usize>,
    /// Final relative residual per column.
    pub residual: Vec<f64>,
}

/// Solve Op X = B column-wise to relative tolerance `tol`; X is in/out
/// (each column warm-starts its system). `active` masks which columns
/// to solve (`None` → all); inactive columns are left untouched.
///
/// Errors mirror [`cg`](super::cg()): a non-positive curvature
/// pᵀ(Op p) on any live column yields [`AltDiffError::NotSpd`]; columns
/// still above `10 × tol` after `max_iter` yield
/// [`AltDiffError::NoConvergence`].
pub fn block_cg<O: SpdBlockOp>(
    op: &O,
    b: &Mat,
    x: &mut Mat,
    tol: f64,
    max_iter: usize,
    active: Option<&[bool]>,
) -> Result<BlockCgInfo, AltDiffError> {
    let n = op.dim();
    let w = b.cols;
    debug_assert_eq!(b.rows, n);
    debug_assert_eq!(x.rows, n);
    debug_assert_eq!(x.cols, w);
    let mut act = ActiveSet::new(w);
    if let Some(flags) = active {
        debug_assert_eq!(flags.len(), w);
        for (e, &f) in flags.iter().enumerate() {
            if !f {
                act.deactivate(e);
            }
        }
    }
    let mut info = BlockCgInfo {
        iters: vec![0; w],
        residual: vec![0.0; w],
    };
    if act.all_done() || n == 0 {
        return Ok(info);
    }
    let minv: Vec<f64> = match op.diag() {
        Some(d) => d.iter().map(|&v| 1.0 / v.max(1e-30)).collect(),
        None => vec![1.0; n],
    };

    let mut ranges = act.col_ranges(1);
    let mut bnorm = vec![0.0; w];
    col_dots(b, b, &ranges, &mut bnorm);
    for &(c0, c1) in &ranges {
        for c in c0..c1 {
            bnorm[c] = bnorm[c].sqrt().max(1e-30);
        }
    }

    // r = B − Op(X)
    let mut r = Mat::zeros(n, w);
    op.apply_block(x, &mut r, &ranges);
    for i in 0..n {
        let br = b.row(i);
        let rr = r.row_mut(i);
        for &(c0, c1) in &ranges {
            for c in c0..c1 {
                rr[c] = br[c] - rr[c];
            }
        }
    }
    // z = M⁻¹r, p = z
    let mut z = Mat::zeros(n, w);
    let mut p = Mat::zeros(n, w);
    let mut ap = Mat::zeros(n, w);
    for i in 0..n {
        let mi = minv[i];
        let rr = r.row(i);
        let zr = z.row_mut(i);
        for &(c0, c1) in &ranges {
            for c in c0..c1 {
                zr[c] = rr[c] * mi;
            }
        }
    }
    for i in 0..n {
        let zr = z.row(i);
        let pr = p.row_mut(i);
        for &(c0, c1) in &ranges {
            for c in c0..c1 {
                pr[c] = zr[c];
            }
        }
    }
    let mut rz = vec![0.0; w];
    col_dots(&r, &z, &ranges, &mut rz);

    let mut rn2 = vec![0.0; w];
    let mut pap = vec![0.0; w];
    let mut alpha = vec![0.0; w];
    let mut beta = vec![0.0; w];
    let mut rz_new = vec![0.0; w];
    for it in 0..max_iter {
        // per-column convergence check (top of the loop, like `cg`)
        col_dots(&r, &r, &ranges, &mut rn2);
        for e in act.iter().collect::<Vec<_>>() {
            let rel = rn2[e].sqrt() / bnorm[e];
            if rel < tol {
                info.iters[e] = it;
                info.residual[e] = rel;
                act.deactivate(e);
            }
        }
        if act.all_done() {
            return Ok(info);
        }
        ranges = act.col_ranges(1);

        op.apply_block(&p, &mut ap, &ranges);
        col_dots(&p, &ap, &ranges, &mut pap);
        for e in act.iter() {
            if pap[e] <= 0.0 || !pap[e].is_finite() {
                return Err(AltDiffError::NotSpd {
                    pivot: it,
                    value: pap[e],
                });
            }
            alpha[e] = rz[e] / pap[e];
        }
        for i in 0..n {
            let pr = p.row(i);
            let apr = ap.row(i);
            let xr = x.row_mut(i);
            for &(c0, c1) in &ranges {
                for c in c0..c1 {
                    xr[c] += alpha[c] * pr[c];
                }
            }
            let rr = r.row_mut(i);
            for &(c0, c1) in &ranges {
                for c in c0..c1 {
                    rr[c] -= alpha[c] * apr[c];
                }
            }
        }
        for i in 0..n {
            let mi = minv[i];
            let rr = r.row(i);
            let zr = z.row_mut(i);
            for &(c0, c1) in &ranges {
                for c in c0..c1 {
                    zr[c] = rr[c] * mi;
                }
            }
        }
        col_dots(&r, &z, &ranges, &mut rz_new);
        for e in act.iter() {
            beta[e] = rz_new[e] / rz[e];
            rz[e] = rz_new[e];
        }
        for i in 0..n {
            let zr = z.row(i);
            let pr = p.row_mut(i);
            for &(c0, c1) in &ranges {
                for c in c0..c1 {
                    pr[c] = zr[c] + beta[c] * pr[c];
                }
            }
        }
    }
    // budget exhausted: accept near-misses (like `cg`), else error
    col_dots(&r, &r, &ranges, &mut rn2);
    for e in act.iter().collect::<Vec<_>>() {
        let rel = rn2[e].sqrt() / bnorm[e];
        if rel < tol * 10.0 {
            info.iters[e] = max_iter;
            info.residual[e] = rel;
            act.deactivate(e);
        } else {
            return Err(AltDiffError::NoConvergence {
                iters: max_iter,
                residual: rel,
            });
        }
    }
    Ok(info)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{cg, HessianOp, SpdOp};
    use crate::util::rng::Pcg64;

    fn problem(
        n: usize,
        p: usize,
        m: usize,
        seed: u64,
    ) -> (Vec<f64>, Csr, Csr) {
        let mut rng = Pcg64::new(seed);
        let pdiag: Vec<f64> = (0..n).map(|_| 1.0 + rng.uniform()).collect();
        let mut ta = Vec::new();
        for i in 0..p {
            for j in 0..n {
                if rng.uniform() < 0.3 {
                    ta.push((i, j, rng.normal()));
                }
            }
        }
        let mut tg = Vec::new();
        for i in 0..m {
            for j in 0..n {
                if rng.uniform() < 0.3 {
                    tg.push((i, j, rng.normal()));
                }
            }
        }
        (
            pdiag,
            Csr::from_triplets(p, n, &ta),
            Csr::from_triplets(m, n, &tg),
        )
    }

    #[test]
    fn block_op_matches_sequential_op() {
        let (pdiag, a, g) = problem(14, 5, 8, 1);
        let rho = 1.3;
        let w = 4;
        let seq_op = HessianOp::new(&pdiag, &a, &g, rho);
        let blk_op = BlockHessianOp::new(&pdiag, &a, &g, rho, w);
        let mut rng = Pcg64::new(2);
        let x = Mat::from_vec(14, w, rng.normal_vec(14 * w));
        let mut y = Mat::zeros(14, w);
        blk_op.apply_block(&x, &mut y, &[(0, w)]);
        for c in 0..w {
            let xc = x.col(c);
            let mut yc = vec![0.0; 14];
            seq_op.apply(&xc, &mut yc);
            for i in 0..14 {
                assert!((y[(i, c)] - yc[i]).abs() < 1e-12, "({i},{c})");
            }
        }
        assert_eq!(blk_op.diag(), seq_op.diag());
    }

    #[test]
    fn block_cg_matches_columnwise_cg() {
        let (pdiag, a, g) = problem(20, 6, 10, 3);
        let rho = 1.0;
        let w = 5;
        let mut rng = Pcg64::new(4);
        let b = Mat::from_vec(20, w, rng.normal_vec(20 * w));
        let blk_op = BlockHessianOp::new(&pdiag, &a, &g, rho, w);
        let mut x = Mat::zeros(20, w);
        let info =
            block_cg(&blk_op, &b, &mut x, 1e-11, 500, None).unwrap();
        let seq_op = HessianOp::new(&pdiag, &a, &g, rho);
        for c in 0..w {
            let bc = b.col(c);
            let mut xc = vec![0.0; 20];
            let si = cg(&seq_op, &bc, &mut xc, 1e-11, 500).unwrap();
            for i in 0..20 {
                assert!(
                    (x[(i, c)] - xc[i]).abs() < 1e-9,
                    "col {c} row {i}: {} vs {}",
                    x[(i, c)],
                    xc[i]
                );
            }
            assert!(
                (info.iters[c] as i64 - si.iters as i64).abs() <= 1,
                "col {c}: {} vs {} iters",
                info.iters[c],
                si.iters
            );
        }
    }

    #[test]
    fn block_cg_masked_columns_untouched() {
        let (pdiag, a, g) = problem(12, 4, 6, 5);
        let w = 3;
        let mut rng = Pcg64::new(6);
        let b = Mat::from_vec(12, w, rng.normal_vec(12 * w));
        let blk_op = BlockHessianOp::new(&pdiag, &a, &g, 1.0, w);
        let mut x = Mat::zeros(12, w);
        for i in 0..12 {
            x[(i, 1)] = 7.0; // poison the masked column
        }
        let active = [true, false, true];
        block_cg(&blk_op, &b, &mut x, 1e-10, 500, Some(&active))
            .unwrap();
        for i in 0..12 {
            assert_eq!(x[(i, 1)], 7.0, "masked column was written");
        }
        // solved columns actually satisfy the system
        let mut y = Mat::zeros(12, w);
        blk_op.apply_block(&x, &mut y, &[(0, 1), (2, 3)]);
        for &c in &[0usize, 2] {
            for i in 0..12 {
                assert!((y[(i, c)] - b[(i, c)]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn warm_started_column_converges_immediately() {
        let (pdiag, a, g) = problem(16, 5, 8, 7);
        let w = 2;
        let mut rng = Pcg64::new(8);
        let b = Mat::from_vec(16, w, rng.normal_vec(16 * w));
        let blk_op = BlockHessianOp::new(&pdiag, &a, &g, 1.0, w);
        let mut x = Mat::zeros(16, w);
        block_cg(&blk_op, &b, &mut x, 1e-12, 1000, None).unwrap();
        // resolve from the solution: 0 iterations per column
        let info =
            block_cg(&blk_op, &b, &mut x, 1e-10, 1000, None).unwrap();
        assert!(info.iters.iter().all(|&it| it <= 1), "{:?}", info.iters);
    }
}
