//! Sparse linear algebra substrate: CSR storage, matrix-free CG, and the
//! blocked (multi-RHS) variants the batched sparse engine runs on.
pub mod block_cg;
pub mod cg;
pub mod csr;

pub use block_cg::{block_cg, BlockCgInfo, BlockHessianOp, SpdBlockOp};
pub use cg::{cg, CgInfo, HessianOp, SpdOp};
pub use csr::Csr;
