//! Sparse linear algebra substrate (CSR + matrix-free CG).
pub mod cg;
pub mod csr;

pub use cg::{cg, CgInfo, HessianOp, SpdOp};
pub use csr::Csr;
