//! Warm-start subsystem: cross-solve iterate reuse.
//!
//! The paper's truncation theorem (§4.3) bounds the gradient error of a
//! truncated Alt-Diff run by the same order as the primal iterate's
//! estimation error — so any mechanism that starts the alternating
//! recursion closer to x* buys accuracy (or, equivalently, lets the run
//! stop earlier at the same accuracy). Serving (repeated solves on
//! slowly-drifting parameters) and training (epoch-over-epoch solves on
//! the same minibatch schedule) are exactly that regime.
//!
//! This module holds the pieces every layer of the stack shares:
//!
//! - [`WarmStart`]: a prior primal/dual iterate triple (x, λ, ν). Every
//!   engine accepts one through its `*_from` entry point
//!   ([`DenseAltDiff::solve_from`](crate::altdiff::DenseAltDiff::solve_from),
//!   [`SparseAltDiff::solve_from`](crate::altdiff::SparseAltDiff::solve_from),
//!   [`BatchedAltDiff::solve_batch_from`](crate::batch::BatchedAltDiff::solve_batch_from),
//!   [`BatchedSparseAltDiff::try_solve_batch_from`](crate::batch::BatchedSparseAltDiff::try_solve_batch_from))
//!   and resumes the ADMM alternation from it; the slack is re-derived
//!   from the warm point via the (6) projection, so the triple is all a
//!   cache needs to store.
//! - [`AdjointSeed`]: the matching reverse-mode state (z, wₛ, w_λ, w_ν).
//!   The adjoint recursion w ← Mᵀw + V converges to its fixed point from
//!   any start, so a seed harvested from a previous backward
//!   ([`DenseAltDiff::vjp_from`](crate::altdiff::DenseAltDiff::vjp_from)
//!   and siblings) shortens the next one the same way the primal warm
//!   start shortens the forward pass.
//! - [`WarmStartCache`]: an LRU map keyed by `(layer, family, k,
//!   fingerprint)` with a staleness radius — a cached iterate is only
//!   handed out when the requesting θ is within a configurable relative
//!   distance of the θ the iterate was solved at. The coordinator
//!   consults it before every native batched launch and writes
//!   converged iterates back after; `nn::OptLayer` and the
//!   `train::{mnist,energy}` loops use the same cache keyed by sample
//!   index. Under the sharded coordinator one cache instance is shared
//!   by every shard behind a single `Arc<Mutex>`, and each lookup/
//!   write-back holds the lock across the whole batch — so concurrent
//!   shards (and stolen batches executing on a sibling shard's worker)
//!   stay linearizable without per-shard cache partitions. Session-
//!   hashed routing means a given session's entries are normally
//!   touched by exactly one shard; steals only move *where* the
//!   write-back happens, never its key or content.
//! - [`EngineFamily`] tags every cache slot with the engine family that
//!   produced the iterate. The primal triple would be a mathematically
//!   valid warm start across families, but the *k* it was truncated at
//!   was calibrated against one family's convergence trajectory, and
//!   the adjoint state is family-specific state-space — so an
//!   ADMM-produced iterate must never seed an Alt-Diff or Frank–Wolfe
//!   solve (and so on across the full family matrix). Cross-family
//!   lookups are structural misses.
//!
//! **Forward-mode caveat.** A warm primal converges before a cold
//! Jacobian recursion does, so warm starts compose with
//! [`BackwardMode::None`](crate::altdiff::BackwardMode) and
//! [`BackwardMode::Adjoint`](crate::altdiff::BackwardMode) at any
//! tolerance, but with [`BackwardMode::Forward`](crate::altdiff::BackwardMode)
//! only at `tol = 0` (fixed-k): there the slack gates are correct from
//! iteration 1, so the fixed-k Jacobian is at least as accurate as the
//! cold one, while a tol-truncated run would stop on the (instantly
//! converged) primal with the Jacobian still garbage. The engines
//! enforce this with an assert. See DESIGN.md §5.

use crate::altdiff::Solution;
use std::collections::HashMap;

/// A prior primal/dual iterate triple to resume the ADMM alternation
/// from. Harvest one from any converged (or truncated) solve with
/// [`WarmStart::of`]; the slack s is *not* stored — engines re-derive it
/// from the warm point via the (6) projection
/// s = max(0, −ν/ρ − (Gx − h)), which at a fixed point reproduces s*.
#[derive(Clone, Debug)]
pub struct WarmStart {
    /// Primal iterate x (length n).
    pub x: Vec<f64>,
    /// Equality duals λ (length p).
    pub lam: Vec<f64>,
    /// Inequality duals ν (length m).
    pub nu: Vec<f64>,
}

impl WarmStart {
    /// Build from explicit iterates.
    pub fn new(x: Vec<f64>, lam: Vec<f64>, nu: Vec<f64>) -> Self {
        WarmStart { x, lam, nu }
    }

    /// Harvest the reusable iterate triple from a finished solve.
    pub fn of(sol: &Solution) -> Self {
        WarmStart {
            x: sol.x.clone(),
            lam: sol.lam.clone(),
            nu: sol.nu.clone(),
        }
    }

    /// Iterate dimensions as `(n, p, m)`.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.x.len(), self.lam.len(), self.nu.len())
    }
}

/// A prior reverse-mode (adjoint) state `(z, wₛ, w_λ, w_ν)` to resume
/// the transposed recursion from — returned by the `vjp_from` /
/// `batch_vjp_from` entry points and stored alongside the forward
/// [`WarmStart`] in the cache. Valid as a starting point for *any*
/// later seed v (the fixed point moves, the iteration still converges);
/// the closer the new v and slack gates are to the old ones, the more
/// iterations it saves.
#[derive(Clone, Debug)]
pub struct AdjointSeed {
    /// Adjoint primal iterate z (length n; also the CG warm start on
    /// the sparse path).
    pub z: Vec<f64>,
    /// Slack adjoint wₛ (length m).
    pub ws: Vec<f64>,
    /// Equality-dual adjoint w_λ (length p).
    pub wl: Vec<f64>,
    /// Inequality-dual adjoint w_ν (length m).
    pub wn: Vec<f64>,
}

impl AdjointSeed {
    /// State dimensions as `(n, p, m)`.
    pub fn dims(&self) -> (usize, usize, usize) {
        (self.z.len(), self.wl.len(), self.ws.len())
    }
}

/// Which differentiable-solver family produced (or will consume) an
/// iterate. The forward [`WarmStart`] triple is portable mathematics,
/// but cached entries are routed-*k* artifacts calibrated per family,
/// and adjoint states live in family-specific state spaces — so the
/// cache keys on this tag and a cross-family lookup is always a miss.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum EngineFamily {
    /// The paper's Algorithm 1 (dense or sparse, single or batched).
    AltDiff,
    /// The consensus-form over-relaxed ADMM family
    /// ([`AdmmQp`](crate::admm::AdmmQp) and
    /// [`BatchedAdmm`](crate::admm::BatchedAdmm)).
    Admm,
    /// The projection-free Frank–Wolfe (conditional-gradient) family
    /// ([`FwQp`](crate::fw::FwQp) and
    /// [`BatchedFw`](crate::fw::BatchedFw)).
    Fw,
}

/// The ADMM family's reverse-mode resume state: the splitting-variable
/// adjoint pair (w_z, w_u), each of length p + m — returned by
/// [`AdmmQp::vjp_from`](crate::admm::AdmmQp::vjp_from) and
/// [`BatchedAdmm::batch_vjp_from`](crate::admm::BatchedAdmm::batch_vjp_from).
#[derive(Clone, Debug)]
pub struct AdmmSeed {
    /// Adjoint of the consensus variable z (length p + m).
    pub wz: Vec<f64>,
    /// Adjoint of the scaled dual u (length p + m).
    pub wu: Vec<f64>,
}

impl AdmmSeed {
    /// Stacked state dimension p + m.
    pub fn dim(&self) -> usize {
        self.wz.len()
    }
}

/// The Frank–Wolfe family's reverse-mode resume state: the projected-CG
/// adjoint iterate y (length n, O(n) — dimension-free like the other
/// families' seeds) — returned by
/// [`FwQp::vjp_from`](crate::fw::FwQp::vjp_from) and
/// [`BatchedFw::batch_vjp_from`](crate::fw::BatchedFw::batch_vjp_from).
#[derive(Clone, Debug)]
pub struct FwSeed {
    /// Adjoint primal iterate y (length n), the CG warm start.
    pub y: Vec<f64>,
}

impl FwSeed {
    /// State dimension n.
    pub fn dim(&self) -> usize {
        self.y.len()
    }
}

/// A family-tagged adjoint resume state, as the cache stores it: the
/// Alt-Diff, ADMM, and Frank–Wolfe backward recursions iterate in
/// different state spaces, so the seed carries its family and the
/// consuming engine unwraps (and the type system rejects) any other
/// family's state.
#[derive(Clone, Debug)]
pub enum EngineSeed {
    /// An Alt-Diff adjoint state `(z, wₛ, w_λ, w_ν)`.
    AltDiff(AdjointSeed),
    /// An ADMM adjoint state `(w_z, w_u)`.
    Admm(AdmmSeed),
    /// A Frank–Wolfe adjoint state (the projected-CG iterate y).
    Fw(FwSeed),
}

impl EngineSeed {
    /// The family whose backward produced this state.
    pub fn family(&self) -> EngineFamily {
        match self {
            EngineSeed::AltDiff(_) => EngineFamily::AltDiff,
            EngineSeed::Admm(_) => EngineFamily::Admm,
            EngineSeed::Fw(_) => EngineFamily::Fw,
        }
    }

    /// Consume into an Alt-Diff seed; `None` for any other family.
    pub fn into_altdiff(self) -> Option<AdjointSeed> {
        match self {
            EngineSeed::AltDiff(s) => Some(s),
            _ => None,
        }
    }

    /// Consume into an ADMM seed; `None` for any other family.
    pub fn into_admm(self) -> Option<AdmmSeed> {
        match self {
            EngineSeed::Admm(s) => Some(s),
            _ => None,
        }
    }

    /// Consume into a Frank–Wolfe seed; `None` for any other family.
    pub fn into_fw(self) -> Option<FwSeed> {
        match self {
            EngineSeed::Fw(s) => Some(s),
            _ => None,
        }
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Cache fingerprint for a request's parameters.
///
/// With a `session` key (the wire protocol's optional client session,
/// or a training loop's sample index) the fingerprint is a hash of the
/// key alone — the drift-robust path: a session's next request hits the
/// same slot however far θ moved, and the [`WarmStartCache`] staleness
/// radius decides whether the stored iterate is still useful.
///
/// Without a session the fingerprint hashes the raw θ bits, so
/// anonymous requests only hit on (near-)exact repeats of the same
/// parameters — still worth having for idempotent retries and repeated
/// oracle solves, but not for drifting workloads.
pub fn fingerprint(
    session: Option<u64>,
    q: &[f64],
    b: &[f64],
    h: &[f64],
) -> u64 {
    if let Some(s) = session {
        // salted so a session key never collides with a content hash
        // except by chance
        return splitmix64(s ^ 0x5e55_10a7_ba5e_d00d);
    }
    // FNV-1a over the raw f64 bits plus the field lengths (so e.g.
    // (q=[v], b=[]) and (q=[], b=[v]) separate)
    let mut acc: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |bits: u64| {
        acc ^= bits;
        acc = acc.wrapping_mul(0x1_0000_0000_01b3);
    };
    for &v in q.iter().chain(b).chain(h) {
        eat(v.to_bits());
    }
    eat(q.len() as u64);
    eat(b.len() as u64);
    eat(h.len() as u64);
    acc
}

/// Relative L2 distance between two θ snapshots (concatenated q, b, h),
/// normalized by the stored snapshot's norm: ‖θ_req − θ_stored‖ /
/// max(‖θ_stored‖, 1). Mismatched dimensions are infinitely far apart.
pub fn theta_distance(
    stored: (&[f64], &[f64], &[f64]),
    req: (&[f64], &[f64], &[f64]),
) -> f64 {
    let (sq, sb, sh) = stored;
    let (rq, rb, rh) = req;
    if sq.len() != rq.len() || sb.len() != rb.len() || sh.len() != rh.len()
    {
        return f64::INFINITY;
    }
    let mut d2 = 0.0;
    let mut n2 = 0.0;
    for (s, r) in sq
        .iter()
        .chain(sb)
        .chain(sh)
        .zip(rq.iter().chain(rb).chain(rh))
    {
        d2 += (s - r) * (s - r);
        n2 += s * s;
    }
    d2.sqrt() / n2.sqrt().max(1.0)
}

/// One cached iterate: the θ it was solved at (for the staleness
/// check), the forward warm triple, and optionally the adjoint state of
/// the backward that followed it.
struct Entry {
    q: Vec<f64>,
    b: Vec<f64>,
    h: Vec<f64>,
    warm: WarmStart,
    adjoint: Option<EngineSeed>,
    stamp: u64,
}

/// FNV-1a of the layer name — hot-path lookups key on this hash
/// instead of cloning the `String`. A 64-bit collision between two
/// registered layer names is astronomically unlikely, and even then
/// harmless: the dimension and staleness checks reject a foreign
/// entry, and a same-shape near-θ iterate is a valid (convergent)
/// warm start anyway.
fn layer_hash(layer: &str) -> u64 {
    let mut acc: u64 = 0xcbf2_9ce4_8422_2325;
    for &byte in layer.as_bytes() {
        acc ^= byte as u64;
        acc = acc.wrapping_mul(0x1_0000_0000_01b3);
    }
    acc
}

/// LRU warm-start cache keyed by `(layer, family, k, fingerprint)`.
///
/// `k` is the routed iteration count the iterate was produced under
/// (callers outside the serving router — `nn::OptLayer`, training
/// loops — use `k = 0` as the "tolerance-routed" sentinel), and
/// `family` is the [`EngineFamily`] that produced the iterate — an
/// ADMM-produced iterate never seeds an Alt-Diff solve of the same
/// `(layer, k, fingerprint)`, or vice versa. Lookups reject entries
/// whose stored θ is farther than the configured `radius` from the
/// requesting θ ([`theta_distance`]), so a slot never hands out an
/// iterate that has drifted out of usefulness; a capacity of 0 disables
/// the cache entirely (every `get` misses, `put` is a no-op — the
/// serving default, so cold fixed-k semantics are opt-out).
///
/// ```
/// use altdiff::warm::{fingerprint, EngineFamily, WarmStart, WarmStartCache};
///
/// let mut cache = WarmStartCache::new(2, 0.5);
/// let q = vec![1.0, 2.0];
/// let fp = fingerprint(Some(7), &q, &[], &[]);
/// let warm = WarmStart::new(vec![0.1, 0.2], vec![], vec![0.0]);
/// let fam = EngineFamily::AltDiff;
/// cache.put("layer", fam, 10, fp, q.clone(), vec![], vec![], warm, None);
/// // same session, slightly drifted θ: within the radius → hit
/// assert!(cache.get("layer", fam, 10, fp, &[1.01, 2.0], &[], &[]).is_some());
/// // same slot, θ far away: stale → miss
/// assert!(cache.get("layer", fam, 10, fp, &[99.0, -50.0], &[], &[]).is_none());
/// // a different routed k is a different slot
/// assert!(cache.get("layer", fam, 20, fp, &[1.0, 2.0], &[], &[]).is_none());
/// // ... and so is the other engine family
/// let admm = EngineFamily::Admm;
/// assert!(cache.get("layer", admm, 10, fp, &[1.0, 2.0], &[], &[]).is_none());
/// assert_eq!((cache.hits(), cache.misses()), (1, 3));
/// ```
pub struct WarmStartCache {
    capacity: usize,
    radius: f64,
    clock: u64,
    hits: u64,
    misses: u64,
    /// keyed (layer-name hash, engine family, routed k, fingerprint) —
    /// see [`layer_hash`] for why the name is hashed rather than cloned
    map: HashMap<(u64, EngineFamily, usize, u64), Entry>,
}

impl WarmStartCache {
    /// Cache holding at most `capacity` entries, handing out iterates
    /// only within the relative staleness `radius` (see
    /// [`theta_distance`]). `capacity = 0` disables the cache.
    pub fn new(capacity: usize, radius: f64) -> Self {
        WarmStartCache {
            capacity,
            radius,
            clock: 0,
            hits: 0,
            misses: 0,
            map: HashMap::new(),
        }
    }

    /// True when the cache can ever hit (capacity > 0).
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Look up a warm iterate for `(layer, family, k, fp)` at the
    /// requesting θ. Misses on absence, dimension mismatch, staleness
    /// (stored θ farther than the radius), or an entry produced by the
    /// other engine family; hits bump the entry's LRU stamp and return
    /// clones (the entry stays cached).
    pub fn get(
        &mut self,
        layer: &str,
        family: EngineFamily,
        k: usize,
        fp: u64,
        q: &[f64],
        b: &[f64],
        h: &[f64],
    ) -> Option<(WarmStart, Option<EngineSeed>)> {
        if self.capacity == 0 {
            return None;
        }
        self.clock += 1;
        let clock = self.clock;
        let key = (layer_hash(layer), family, k, fp);
        match self.map.get_mut(&key) {
            Some(e)
                if theta_distance(
                    (&e.q, &e.b, &e.h),
                    (q, b, h),
                ) <= self.radius =>
            {
                e.stamp = clock;
                self.hits += 1;
                Some((e.warm.clone(), e.adjoint.clone()))
            }
            _ => {
                self.misses += 1;
                None
            }
        }
    }

    /// Insert (or replace) the iterate for `(layer, family, k, fp)`,
    /// recording the θ it was solved at for later staleness checks.
    /// Evicts the least-recently-used entry when over capacity.
    /// `adjoint = None` clears any previously stored seed (solve-path
    /// writes invalidate the adjoint state, whose gates belonged to the
    /// old forward).
    #[allow(clippy::too_many_arguments)]
    pub fn put(
        &mut self,
        layer: &str,
        family: EngineFamily,
        k: usize,
        fp: u64,
        q: Vec<f64>,
        b: Vec<f64>,
        h: Vec<f64>,
        warm: WarmStart,
        adjoint: Option<EngineSeed>,
    ) {
        if self.capacity == 0 {
            return;
        }
        self.clock += 1;
        self.map.insert(
            (layer_hash(layer), family, k, fp),
            Entry { q, b, h, warm, adjoint, stamp: self.clock },
        );
        // LRU eviction by a min-stamp scan: O(capacity), but the scan
        // is pure integer compares over a map that tops out at a few
        // thousand entries — noise next to the O(k·n²)-scale solve
        // each put amortizes against.
        while self.map.len() > self.capacity {
            let lru = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| *k)
                .expect("nonempty over-capacity cache");
            self.map.remove(&lru);
        }
    }

    /// Cached entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Lookups that returned an iterate.
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Lookups that found nothing usable (absent, stale, or mismatched
    /// dimensions). Disabled-cache lookups count as neither.
    pub fn misses(&self) -> u64 {
        self.misses
    }

    /// Drop every entry (counters survive).
    pub fn clear(&mut self) {
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALT: EngineFamily = EngineFamily::AltDiff;
    const ADMM: EngineFamily = EngineFamily::Admm;
    const FW: EngineFamily = EngineFamily::Fw;

    fn warm(n: usize) -> WarmStart {
        WarmStart::new(vec![1.0; n], vec![0.5; 1], vec![0.25; 2])
    }

    #[test]
    fn hit_requires_radius_and_key_match() {
        let mut c = WarmStartCache::new(4, 0.1);
        let q = vec![1.0, 1.0];
        let fp = fingerprint(Some(3), &q, &[], &[]);
        c.put("l", ALT, 10, fp, q.clone(), vec![], vec![], warm(2), None);
        assert!(c.get("l", ALT, 10, fp, &[1.0, 1.0], &[], &[]).is_some());
        assert!(c.get("l", ALT, 10, fp, &[1.05, 1.0], &[], &[]).is_some());
        // beyond the 0.1 relative radius
        assert!(c.get("l", ALT, 10, fp, &[2.0, 1.0], &[], &[]).is_none());
        // different layer / family / k / fingerprint: different slots
        assert!(c.get("m", ALT, 10, fp, &q, &[], &[]).is_none());
        assert!(c.get("l", ADMM, 10, fp, &q, &[], &[]).is_none());
        assert!(c.get("l", ALT, 20, fp, &q, &[], &[]).is_none());
        assert!(c.get("l", ALT, 10, fp ^ 1, &q, &[], &[]).is_none());
        assert_eq!(c.hits(), 2);
        assert_eq!(c.misses(), 5);
    }

    #[test]
    fn dimension_mismatch_is_a_miss() {
        let mut c = WarmStartCache::new(4, 10.0);
        let fp = fingerprint(Some(1), &[1.0], &[], &[]);
        c.put("l", ALT, 0, fp, vec![1.0], vec![], vec![], warm(1), None);
        assert!(c.get("l", ALT, 0, fp, &[1.0, 2.0], &[], &[]).is_none());
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = WarmStartCache::new(2, 1.0);
        let fps: Vec<u64> =
            (0..3).map(|i| fingerprint(Some(i), &[], &[], &[])).collect();
        c.put("l", ALT, 0, fps[0], vec![1.0], vec![], vec![], warm(1), None);
        c.put("l", ALT, 0, fps[1], vec![1.0], vec![], vec![], warm(1), None);
        // touch slot 0 so slot 1 becomes the LRU
        assert!(c.get("l", ALT, 0, fps[0], &[1.0], &[], &[]).is_some());
        c.put("l", ALT, 0, fps[2], vec![1.0], vec![], vec![], warm(1), None);
        assert_eq!(c.len(), 2);
        assert!(c.get("l", ALT, 0, fps[0], &[1.0], &[], &[]).is_some());
        assert!(c.get("l", ALT, 0, fps[1], &[1.0], &[], &[]).is_none());
        assert!(c.get("l", ALT, 0, fps[2], &[1.0], &[], &[]).is_some());
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = WarmStartCache::new(0, 1.0);
        assert!(!c.enabled());
        let fp = fingerprint(None, &[1.0], &[], &[]);
        c.put("l", ALT, 0, fp, vec![1.0], vec![], vec![], warm(1), None);
        assert!(c.get("l", ALT, 0, fp, &[1.0], &[], &[]).is_none());
        assert_eq!(c.len(), 0);
        assert_eq!((c.hits(), c.misses()), (0, 0));
    }

    #[test]
    fn cross_family_seeding_is_a_miss() {
        // one family's iterate must never seed another family's solve
        // of the same (layer, k, fingerprint) — the full 3×3 matrix:
        // every off-diagonal (producer, consumer) pair is a structural
        // miss, every diagonal pair hits with its own typed seed
        let families = [ALT, ADMM, FW];
        let mk_seed = |f: EngineFamily| match f {
            EngineFamily::AltDiff => EngineSeed::AltDiff(AdjointSeed {
                z: vec![0.5, 0.5],
                ws: vec![0.1],
                wl: vec![0.2],
                wn: vec![0.3],
            }),
            EngineFamily::Admm => EngineSeed::Admm(AdmmSeed {
                wz: vec![0.1, 0.2, 0.3],
                wu: vec![0.4, 0.5, 0.6],
            }),
            EngineFamily::Fw => {
                EngineSeed::Fw(FwSeed { y: vec![0.7, 0.8] })
            }
        };
        let q = vec![1.0, 1.0];
        let fp = fingerprint(Some(42), &q, &[], &[]);
        // off-diagonal pairs: only the producer's entry exists, every
        // other consumer family misses structurally
        for producer in families {
            let mut c = WarmStartCache::new(8, 10.0);
            c.put(
                "l",
                producer,
                10,
                fp,
                q.clone(),
                vec![],
                vec![],
                warm(2),
                Some(mk_seed(producer)),
            );
            for consumer in families {
                let hit = c.get("l", consumer, 10, fp, &q, &[], &[]);
                if consumer != producer {
                    assert!(
                        hit.is_none(),
                        "{consumer:?} must never resume from a \
                         {producer:?} iterate"
                    );
                    continue;
                }
                let (_, adj) = hit.expect("own-family entry hits");
                let adj = adj.expect("seed survives in its own family");
                assert_eq!(adj.family(), producer);
                // the typed unwraps reject every other family too
                assert_eq!(
                    adj.clone().into_altdiff().is_some(),
                    producer == ALT
                );
                assert_eq!(
                    adj.clone().into_admm().is_some(),
                    producer == ADMM
                );
                assert_eq!(adj.into_fw().is_some(), producer == FW);
            }
        }
        // all three family slots coexist under one (layer, k, fp):
        // no family's put clobbers another's
        let mut c = WarmStartCache::new(8, 10.0);
        for f in families {
            c.put(
                "l",
                f,
                10,
                fp,
                q.clone(),
                vec![],
                vec![],
                warm(2),
                Some(mk_seed(f)),
            );
        }
        assert_eq!(c.len(), 3);
        for f in families {
            let (_, adj) = c
                .get("l", f, 10, fp, &q, &[], &[])
                .expect("own slot survives the other families' puts");
            assert_eq!(adj.expect("typed seed kept").family(), f);
        }
    }

    #[test]
    fn anonymous_fingerprint_is_content_addressed() {
        let a = fingerprint(None, &[1.0, 2.0], &[3.0], &[]);
        let b = fingerprint(None, &[1.0, 2.0], &[3.0], &[]);
        let c = fingerprint(None, &[1.0, 2.0], &[], &[3.0]);
        let d = fingerprint(None, &[1.0, 2.0], &[3.0 + 1e-12], &[]);
        assert_eq!(a, b);
        assert_ne!(a, c, "field boundaries must separate");
        assert_ne!(a, d, "content-addressed: any bit change re-keys");
        // session keys ignore content entirely
        assert_eq!(
            fingerprint(Some(9), &[1.0], &[], &[]),
            fingerprint(Some(9), &[7.0], &[2.0], &[])
        );
    }

    #[test]
    fn put_replaces_and_adjoint_round_trips() {
        let mut c = WarmStartCache::new(2, 1.0);
        let fp = fingerprint(Some(5), &[], &[], &[]);
        c.put("l", ALT, 0, fp, vec![1.0], vec![], vec![], warm(1), None);
        let seed = EngineSeed::AltDiff(AdjointSeed {
            z: vec![0.5],
            ws: vec![0.1, 0.2],
            wl: vec![0.3],
            wn: vec![0.4, 0.5],
        });
        c.put(
            "l",
            ALT,
            0,
            fp,
            vec![1.0],
            vec![],
            vec![],
            warm(1),
            Some(seed),
        );
        assert_eq!(c.len(), 1);
        let (_, adj) = c.get("l", ALT, 0, fp, &[1.0], &[], &[]).unwrap();
        let adj = adj
            .expect("adjoint seed survives")
            .into_altdiff()
            .expect("stored as Alt-Diff state");
        assert_eq!(adj.dims(), (1, 1, 2));
        assert_eq!(adj.ws, vec![0.1, 0.2]);
    }

    #[test]
    fn theta_distance_basics() {
        let d = theta_distance(
            (&[1.0, 0.0], &[], &[]),
            (&[1.0, 0.0], &[], &[]),
        );
        assert_eq!(d, 0.0);
        let d = theta_distance((&[3.0, 4.0], &[], &[]), (&[3.0, 3.0], &[], &[]));
        assert!((d - 1.0 / 5.0).abs() < 1e-12);
        assert!(theta_distance((&[1.0], &[], &[]), (&[1.0, 2.0], &[], &[]))
            .is_infinite());
    }
}
