//! Truncation policy: tolerance → iteration count (and, for
//! dual-family layers, tolerance → engine family).
//!
//! The paper's §4.3 result (gradient error = O(iterate error), Thm 4.3)
//! makes truncation safe; serving makes it *discrete*: compiled variants
//! exist for a ladder of iteration counts k, so the router needs a
//! calibrated map tol → smallest k whose expected relative step falls
//! below tol.
//!
//! Calibration: run the native engine on a representative instance of the
//! registered layer, record the first iteration at which the truncation
//! criterion ‖x_{k+1}−x_k‖/max(‖x_k‖,1) crosses each tolerance, then snap
//! up to the artifact ladder. The table self-corrects online: if an
//! executed batch reports a dual residual above the requested tolerance,
//! the entry for that tolerance is bumped to the next rung.
//!
//! [`EngineRouter`] extends the same idea across *engine families*: at
//! registration both the Alt-Diff and ADMM engines run fixed-k probe
//! solves at every ladder rung, the KKT residual of each probe is
//! recorded, and per calibrated tolerance the family that certifies the
//! tolerance at the smaller rung wins (ties go to Alt-Diff, the paper's
//! engine). See DESIGN.md §6.

use crate::warm::EngineFamily;
use std::collections::BTreeMap;

/// Calibrated tol → k table over a fixed k-ladder.
#[derive(Clone, Debug)]
pub struct TruncationTable {
    /// ascending iteration ladder available as compiled artifacts
    ladder: Vec<usize>,
    /// map from tolerance (as sortable bits, descending tol) to chosen k
    entries: BTreeMap<u64, usize>,
}

fn tol_key(tol: f64) -> u64 {
    // total-order key for positive floats
    tol.to_bits()
}

impl TruncationTable {
    /// Build from a convergence trace: `trace[i]` = relative step at
    /// iteration i (from `altdiff::Solution::trace`).
    ///
    /// ```
    /// use altdiff::coordinator::TruncationTable;
    ///
    /// // relative step shrinks geometrically: 0.5^i per iteration
    /// let trace: Vec<f64> = (0..40).map(|i| 0.5f64.powi(i)).collect();
    /// let table =
    ///     TruncationTable::calibrate(&[10, 20, 40], &trace, &[1e-2, 1e-6]);
    /// // 0.5^7 < 1e-2 → 8 iterations needed → snaps up to rung 10
    /// assert_eq!(table.k_for(1e-2), 10);
    /// // tighter tolerance routes to a higher rung, never lower
    /// assert!(table.k_for(1e-6) >= table.k_for(1e-2));
    /// // uncalibrated-but-looser tolerances reuse a safe entry
    /// assert_eq!(table.k_for(5e-2), table.k_for(1e-2));
    /// ```
    pub fn calibrate(ladder: &[usize], trace: &[f64], tols: &[f64]) -> Self {
        assert!(!ladder.is_empty(), "empty artifact ladder");
        let mut ladder = ladder.to_vec();
        ladder.sort_unstable();
        let mut entries = BTreeMap::new();
        for &tol in tols {
            // first iteration where the criterion holds
            let needed = trace
                .iter()
                .position(|&s| s < tol)
                .map(|i| i + 1)
                .unwrap_or(*ladder.last().unwrap());
            let k = *ladder
                .iter()
                .find(|&&k| k >= needed)
                .unwrap_or(ladder.last().unwrap());
            entries.insert(tol_key(tol), k);
        }
        TruncationTable { ladder, entries }
    }

    /// Uncalibrated fallback: everything maps to the largest k.
    pub fn conservative(ladder: &[usize]) -> Self {
        let mut ladder = ladder.to_vec();
        ladder.sort_unstable();
        TruncationTable { ladder, entries: BTreeMap::new() }
    }

    /// Iterations to run for a requested tolerance: the calibrated entry
    /// for the tightest calibrated tolerance ≤ requested, else max rung.
    ///
    /// This is the *clamping* lookup (benches and offline callers):
    /// a tolerance tighter than everything calibrated silently maps to
    /// the top rung, which may not actually achieve it. The serving
    /// router uses [`Self::k_for_checked`] instead, which refuses such
    /// requests so the coordinator can answer
    /// `FailureKind::Invalid` rather than quietly under-serve.
    pub fn k_for(&self, tol: f64) -> usize {
        self.k_for_checked(tol)
            .unwrap_or(*self.ladder.last().unwrap())
    }

    /// [`Self::k_for`] without the silent clamp: `None` when the
    /// requested tolerance is strictly tighter than every calibrated
    /// tolerance, i.e. the table has no entry that certifies it and the
    /// required iteration count would exceed the registered ladder's
    /// calibrated range. The coordinator maps `None` to a
    /// [`crate::coordinator::FailureKind::Invalid`] failure whose
    /// message names the tightest calibrated tolerance, instead of
    /// silently serving the top rung at unknown accuracy.
    pub fn k_for_checked(&self, tol: f64) -> Option<usize> {
        // exact entry
        if let Some(&k) = self.entries.get(&tol_key(tol)) {
            return Some(k);
        }
        // tightest calibrated tolerance that is <= requested tol is safe
        // (more iterations than strictly needed, never fewer).
        let mut best: Option<usize> = None;
        let mut best_tol = 0.0f64;
        for (&key, &k) in &self.entries {
            let t = f64::from_bits(key);
            if t <= tol && t > best_tol {
                best_tol = t;
                best = Some(k);
            }
        }
        best
    }

    /// The tightest tolerance the table was calibrated for (the lower
    /// bound of what [`Self::k_for_checked`] accepts); `None` for an
    /// uncalibrated [`Self::conservative`] table.
    pub fn tightest_calibrated(&self) -> Option<f64> {
        self.entries
            .keys()
            .map(|&k| f64::from_bits(k))
            .fold(None, |acc: Option<f64>, t| {
                Some(acc.map_or(t, |a| a.min(t)))
            })
    }

    /// Online correction: the executed batch at tolerance `tol` reported a
    /// residual above target → move that tolerance one rung up the ladder.
    pub fn bump(&mut self, tol: f64) {
        let cur = self.k_for(tol);
        let next = self
            .ladder
            .iter()
            .find(|&&k| k > cur)
            .copied()
            .unwrap_or(cur);
        self.entries.insert(tol_key(tol), next);
    }

    /// The ascending artifact iteration ladder.
    pub fn ladder(&self) -> &[usize] {
        &self.ladder
    }
}

/// Per-layer cross-family routing table, calibrated at registration
/// from fixed-k probe solves of every servable engine family.
///
/// For each rung k of the artifact ladder, each family ran the
/// registered θ for exactly k iterations and the resulting KKT residual
/// was recorded (residual-anchored, not step-anchored: the truncation
/// step criterion measures progress per iteration, which flatters a
/// slowly-crawling fixed-ρ run — the KKT residual measures distance to
/// the answer). Per calibrated tolerance, each family's cost is the
/// smallest rung whose probe residual certifies the tolerance (top rung
/// when none does), and the family with the strictly smaller rung wins;
/// ties keep Alt-Diff, the paper's engine.
///
/// ```
/// use altdiff::coordinator::EngineRouter;
/// use altdiff::warm::EngineFamily;
///
/// // Alt-Diff stalls near 1e-1 while ADMM reaches 1e-5 by rung 20
/// let router = EngineRouter::from_probes(
///     &[10, 20, 40],
///     &[2e-1, 1.5e-1, 1.2e-1],
///     &[1e-3, 1e-5, 1e-7],
///     &[1e-2, 1e-4],
///     500.0,
///     (8, 4, 2),
/// );
/// assert_eq!(
///     router.route_checked(1e-2),
///     Some((EngineFamily::Admm, 10))
/// );
/// // tighter than everything calibrated: refused, like the table
/// assert_eq!(router.route_checked(1e-9), None);
/// ```
#[derive(Clone, Debug)]
pub struct EngineRouter {
    ladder: Vec<usize>,
    /// tolerance bits → the winning family and its rung
    entries: BTreeMap<u64, (EngineFamily, usize)>,
    /// conditioning probe recorded at calibration, (max ℓᵢᵢ/min ℓᵢᵢ)²
    /// of the registration Cholesky — observability only
    cond: f64,
    dims: (usize, usize, usize),
}

impl EngineRouter {
    /// Build from per-rung probe residuals. `alt_residuals[i]` and
    /// `admm_residuals[i]` are the KKT residuals after exactly
    /// `ladder[i]` iterations of the respective family on the
    /// registered θ; `cond` is the layer's conditioning probe and
    /// `dims = (n, m, p)`.
    pub fn from_probes(
        ladder: &[usize],
        alt_residuals: &[f64],
        admm_residuals: &[f64],
        tols: &[f64],
        cond: f64,
        dims: (usize, usize, usize),
    ) -> Self {
        Self::from_family_probes(
            ladder,
            &[
                (EngineFamily::AltDiff, alt_residuals),
                (EngineFamily::Admm, admm_residuals),
            ],
            tols,
            cond,
            dims,
        )
    }

    /// The general N-family construction behind [`Self::from_probes`]:
    /// one `(family, per-rung KKT residuals)` pair per calibrated
    /// engine, in *preference order* — per tolerance the family with
    /// the strictly smallest certifying rung wins, and ties keep the
    /// earliest probe in the list (the coordinator passes Alt-Diff
    /// first, so ties still fall to the paper's engine). Families whose
    /// probe could not run (e.g. FW on a non-vertex-enumerable set)
    /// are simply absent from the list.
    pub fn from_family_probes(
        ladder: &[usize],
        probes: &[(EngineFamily, &[f64])],
        tols: &[f64],
        cond: f64,
        dims: (usize, usize, usize),
    ) -> Self {
        assert!(!ladder.is_empty(), "empty artifact ladder");
        assert!(!probes.is_empty(), "no engine probes");
        for (fam, residuals) in probes {
            assert_eq!(
                ladder.len(),
                residuals.len(),
                "probe arity ({fam:?})"
            );
        }
        let mut order: Vec<usize> = (0..ladder.len()).collect();
        order.sort_unstable_by_key(|&i| ladder[i]);
        let sorted: Vec<usize> = order.iter().map(|&i| ladder[i]).collect();
        let cost = |residuals: &[f64], tol: f64| -> usize {
            order
                .iter()
                .find(|&&i| residuals[i] <= tol)
                .map(|&i| ladder[i])
                .unwrap_or(*sorted.last().unwrap())
        };
        let mut entries = BTreeMap::new();
        for &tol in tols {
            let mut pick = (probes[0].0, cost(probes[0].1, tol));
            for &(fam, residuals) in &probes[1..] {
                let k = cost(residuals, tol);
                if k < pick.1 {
                    pick = (fam, k);
                }
            }
            entries.insert(tol_key(tol), pick);
        }
        EngineRouter { ladder: sorted, entries, cond, dims }
    }

    /// The winning `(family, k)` for a requested tolerance: the exact
    /// calibrated entry, else the entry of the tightest calibrated
    /// tolerance ≤ requested (safe: more accuracy than asked for), else
    /// `None` — same refusal semantics as
    /// [`TruncationTable::k_for_checked`], so the coordinator can answer
    /// `FailureKind::Invalid` naming the tightest calibrated tolerance.
    pub fn route_checked(&self, tol: f64) -> Option<(EngineFamily, usize)> {
        if let Some(&pick) = self.entries.get(&tol_key(tol)) {
            return Some(pick);
        }
        let mut best: Option<(EngineFamily, usize)> = None;
        let mut best_tol = 0.0f64;
        for (&key, &pick) in &self.entries {
            let t = f64::from_bits(key);
            if t <= tol && t > best_tol {
                best_tol = t;
                best = Some(pick);
            }
        }
        best
    }

    /// The tightest tolerance the router was calibrated for; `None`
    /// only for an empty tolerance list.
    pub fn tightest_calibrated(&self) -> Option<f64> {
        self.entries
            .keys()
            .map(|&k| f64::from_bits(k))
            .fold(None, |acc: Option<f64>, t| {
                Some(acc.map_or(t, |a| a.min(t)))
            })
    }

    /// Calibrated `(tol, family, k)` rows, ascending by tolerance bits —
    /// for tests and the layers listing.
    pub fn entries(&self) -> Vec<(f64, EngineFamily, usize)> {
        self.entries
            .iter()
            .map(|(&key, &(fam, k))| (f64::from_bits(key), fam, k))
            .collect()
    }

    /// The conditioning probe recorded at calibration.
    pub fn cond(&self) -> f64 {
        self.cond
    }

    /// Problem dimensions `(n, m, p)` recorded at calibration.
    pub fn dims(&self) -> (usize, usize, usize) {
        self.dims
    }

    /// The ascending artifact iteration ladder.
    pub fn ladder(&self) -> &[usize] {
        &self.ladder
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geometric_trace(len: usize, rate: f64) -> Vec<f64> {
        (0..len).map(|i| rate.powi(i as i32)).collect()
    }

    #[test]
    fn calibrate_monotone_in_tol() {
        // step shrinks by 0.7 per iter: tighter tol → larger k
        let trace = geometric_trace(100, 0.7);
        let t = TruncationTable::calibrate(
            &[10, 20, 40, 80],
            &trace,
            &[1e-1, 1e-2, 1e-3, 1e-6],
        );
        let ks: Vec<usize> =
            [1e-1, 1e-2, 1e-3, 1e-6].iter().map(|&x| t.k_for(x)).collect();
        assert!(ks[0] <= ks[1] && ks[1] <= ks[2] && ks[2] <= ks[3], "{ks:?}");
        assert_eq!(ks[0], 10); // 0.7^7 < 0.1 → needs 8 iters → rung 10
        assert_eq!(ks[3], 40); // 0.7^39 ~ 9e-7 → rung 40
    }

    #[test]
    fn uncalibrated_tol_uses_safe_entry() {
        let trace = geometric_trace(100, 0.7);
        let t = TruncationTable::calibrate(
            &[10, 20, 40, 80],
            &trace,
            &[1e-2, 1e-4],
        );
        // 1e-3 not calibrated: must pick the 1e-4 entry (safe, tighter)
        assert_eq!(t.k_for(1e-3), t.k_for(1e-4));
        // 1e-1 not calibrated, nothing tighter→ k_for(1e-2) is <= tol? 1e-2<=1e-1 yes
        assert_eq!(t.k_for(1e-1), t.k_for(1e-2));
    }

    #[test]
    fn never_converging_trace_maps_to_max() {
        let trace = vec![1.0; 50];
        let t =
            TruncationTable::calibrate(&[10, 20, 40], &trace, &[1e-3]);
        assert_eq!(t.k_for(1e-3), 40);
    }

    #[test]
    fn bump_moves_up_ladder_and_saturates() {
        let trace = geometric_trace(100, 0.5);
        let mut t =
            TruncationTable::calibrate(&[10, 20, 40], &trace, &[1e-2]);
        let k0 = t.k_for(1e-2);
        t.bump(1e-2);
        let k1 = t.k_for(1e-2);
        assert!(k1 > k0);
        t.bump(1e-2);
        t.bump(1e-2);
        t.bump(1e-2);
        assert_eq!(t.k_for(1e-2), 40); // saturates at top rung
    }

    #[test]
    fn conservative_always_max() {
        let t = TruncationTable::conservative(&[10, 80, 40]);
        assert_eq!(t.k_for(1e-1), 80);
        assert_eq!(t.k_for(1e-9), 80);
    }

    #[test]
    fn checked_lookup_refuses_beyond_calibrated_range() {
        let trace = geometric_trace(100, 0.7);
        let t = TruncationTable::calibrate(
            &[10, 20, 40, 80],
            &trace,
            &[1e-1, 1e-4],
        );
        // calibrated and covered tolerances route normally
        assert_eq!(t.k_for_checked(1e-1), Some(t.k_for(1e-1)));
        assert_eq!(t.k_for_checked(1e-2), Some(t.k_for(1e-4)));
        assert_eq!(t.k_for_checked(5e-1), Some(t.k_for(1e-1)));
        // tighter than everything calibrated: refused, not clamped
        assert_eq!(t.k_for_checked(1e-9), None);
        // ... while the clamping lookup still serves the top rung
        assert_eq!(t.k_for(1e-9), 80);
        assert_eq!(t.tightest_calibrated(), Some(1e-4));
        let c = TruncationTable::conservative(&[10, 20]);
        assert_eq!(c.k_for_checked(1e-3), None);
        assert_eq!(c.tightest_calibrated(), None);
    }

    #[test]
    fn router_picks_smaller_rung_and_breaks_ties_altdiff() {
        // ADMM certifies 1e-2 at rung 10; Alt-Diff needs rung 40
        let r = EngineRouter::from_probes(
            &[10, 20, 40],
            &[5e-1, 1e-1, 5e-3],
            &[5e-3, 1e-5, 1e-8],
            &[1e-2, 1e-4],
            100.0,
            (10, 5, 2),
        );
        assert_eq!(r.route_checked(1e-2), Some((EngineFamily::Admm, 10)));
        assert_eq!(r.route_checked(1e-4), Some((EngineFamily::Admm, 20)));
        // equal rung → Alt-Diff keeps the layer
        let tie = EngineRouter::from_probes(
            &[10, 20],
            &[1e-3, 1e-6],
            &[1e-3, 1e-6],
            &[1e-2],
            1.0,
            (4, 2, 1),
        );
        assert_eq!(
            tie.route_checked(1e-2),
            Some((EngineFamily::AltDiff, 10))
        );
    }

    #[test]
    fn router_checked_semantics_match_table() {
        let r = EngineRouter::from_probes(
            &[10, 20, 40],
            &[1e-2, 1e-4, 1e-6],
            &[1e-1, 1e-3, 1e-5],
            &[1e-3, 1e-5],
            10.0,
            (6, 3, 1),
        );
        // uncalibrated looser tol reuses the tightest safe entry
        assert_eq!(r.route_checked(1e-4), r.route_checked(1e-5));
        // tighter than calibrated: refused
        assert_eq!(r.route_checked(1e-9), None);
        assert_eq!(r.tightest_calibrated(), Some(1e-5));
        assert_eq!(r.entries().len(), 2);
        assert_eq!(r.ladder(), &[10, 20, 40]);
        assert_eq!(r.dims(), (6, 3, 1));
        assert!((r.cond() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn three_family_probes_pick_fw_on_strict_win() {
        // FW certifies 1e-4 at rung 10; the factorizing families need
        // 20 and 40 — FW takes the layer
        let r = EngineRouter::from_family_probes(
            &[10, 20, 40],
            &[
                (EngineFamily::AltDiff, &[1e-2, 1e-3, 1e-5][..]),
                (EngineFamily::Fw, &[1e-5, 1e-8, 1e-10][..]),
                (EngineFamily::Admm, &[1e-3, 1e-5, 1e-7][..]),
            ],
            &[1e-2, 1e-4],
            3.0,
            (8, 256, 0),
        );
        assert_eq!(r.route_checked(1e-2), Some((EngineFamily::Fw, 10)));
        assert_eq!(r.route_checked(1e-4), Some((EngineFamily::Fw, 10)));
        // a three-way tie keeps the earliest probe: Alt-Diff
        let tie = EngineRouter::from_family_probes(
            &[10],
            &[
                (EngineFamily::AltDiff, &[1e-6][..]),
                (EngineFamily::Fw, &[1e-6][..]),
                (EngineFamily::Admm, &[1e-6][..]),
            ],
            &[1e-4],
            1.0,
            (4, 8, 0),
        );
        assert_eq!(
            tie.route_checked(1e-4),
            Some((EngineFamily::AltDiff, 10))
        );
        // FW absent from the probe list (undetectable set) never wins
        let no_fw = EngineRouter::from_family_probes(
            &[10],
            &[
                (EngineFamily::AltDiff, &[1e-2][..]),
                (EngineFamily::Admm, &[1e-6][..]),
            ],
            &[1e-4],
            1.0,
            (4, 8, 2),
        );
        assert_eq!(
            no_fw.route_checked(1e-4),
            Some((EngineFamily::Admm, 10))
        );
    }

    #[test]
    fn router_unreached_tolerance_costs_top_rung() {
        // neither family certifies 1e-8 → both cost the top rung → tie
        let r = EngineRouter::from_probes(
            &[10, 20],
            &[1.0, 0.5],
            &[1.0, 0.9],
            &[1e-8],
            1e6,
            (8, 4, 2),
        );
        assert_eq!(
            r.route_checked(1e-8),
            Some((EngineFamily::AltDiff, 20))
        );
    }
}
