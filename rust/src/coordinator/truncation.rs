//! Truncation policy: tolerance → iteration count.
//!
//! The paper's §4.3 result (gradient error = O(iterate error), Thm 4.3)
//! makes truncation safe; serving makes it *discrete*: compiled variants
//! exist for a ladder of iteration counts k, so the router needs a
//! calibrated map tol → smallest k whose expected relative step falls
//! below tol.
//!
//! Calibration: run the native engine on a representative instance of the
//! registered layer, record the first iteration at which the truncation
//! criterion ‖x_{k+1}−x_k‖/max(‖x_k‖,1) crosses each tolerance, then snap
//! up to the artifact ladder. The table self-corrects online: if an
//! executed batch reports a dual residual above the requested tolerance,
//! the entry for that tolerance is bumped to the next rung.

use std::collections::BTreeMap;

/// Calibrated tol → k table over a fixed k-ladder.
#[derive(Clone, Debug)]
pub struct TruncationTable {
    /// ascending iteration ladder available as compiled artifacts
    ladder: Vec<usize>,
    /// map from tolerance (as sortable bits, descending tol) to chosen k
    entries: BTreeMap<u64, usize>,
}

fn tol_key(tol: f64) -> u64 {
    // total-order key for positive floats
    tol.to_bits()
}

impl TruncationTable {
    /// Build from a convergence trace: `trace[i]` = relative step at
    /// iteration i (from `altdiff::Solution::trace`).
    ///
    /// ```
    /// use altdiff::coordinator::TruncationTable;
    ///
    /// // relative step shrinks geometrically: 0.5^i per iteration
    /// let trace: Vec<f64> = (0..40).map(|i| 0.5f64.powi(i)).collect();
    /// let table =
    ///     TruncationTable::calibrate(&[10, 20, 40], &trace, &[1e-2, 1e-6]);
    /// // 0.5^7 < 1e-2 → 8 iterations needed → snaps up to rung 10
    /// assert_eq!(table.k_for(1e-2), 10);
    /// // tighter tolerance routes to a higher rung, never lower
    /// assert!(table.k_for(1e-6) >= table.k_for(1e-2));
    /// // uncalibrated-but-looser tolerances reuse a safe entry
    /// assert_eq!(table.k_for(5e-2), table.k_for(1e-2));
    /// ```
    pub fn calibrate(ladder: &[usize], trace: &[f64], tols: &[f64]) -> Self {
        assert!(!ladder.is_empty(), "empty artifact ladder");
        let mut ladder = ladder.to_vec();
        ladder.sort_unstable();
        let mut entries = BTreeMap::new();
        for &tol in tols {
            // first iteration where the criterion holds
            let needed = trace
                .iter()
                .position(|&s| s < tol)
                .map(|i| i + 1)
                .unwrap_or(*ladder.last().unwrap());
            let k = *ladder
                .iter()
                .find(|&&k| k >= needed)
                .unwrap_or(ladder.last().unwrap());
            entries.insert(tol_key(tol), k);
        }
        TruncationTable { ladder, entries }
    }

    /// Uncalibrated fallback: everything maps to the largest k.
    pub fn conservative(ladder: &[usize]) -> Self {
        let mut ladder = ladder.to_vec();
        ladder.sort_unstable();
        TruncationTable { ladder, entries: BTreeMap::new() }
    }

    /// Iterations to run for a requested tolerance: the calibrated entry
    /// for the tightest calibrated tolerance ≤ requested, else max rung.
    ///
    /// This is the *clamping* lookup (benches and offline callers):
    /// a tolerance tighter than everything calibrated silently maps to
    /// the top rung, which may not actually achieve it. The serving
    /// router uses [`Self::k_for_checked`] instead, which refuses such
    /// requests so the coordinator can answer
    /// `FailureKind::Invalid` rather than quietly under-serve.
    pub fn k_for(&self, tol: f64) -> usize {
        self.k_for_checked(tol)
            .unwrap_or(*self.ladder.last().unwrap())
    }

    /// [`Self::k_for`] without the silent clamp: `None` when the
    /// requested tolerance is strictly tighter than every calibrated
    /// tolerance, i.e. the table has no entry that certifies it and the
    /// required iteration count would exceed the registered ladder's
    /// calibrated range. The coordinator maps `None` to a
    /// [`crate::coordinator::FailureKind::Invalid`] failure whose
    /// message names the tightest calibrated tolerance, instead of
    /// silently serving the top rung at unknown accuracy.
    pub fn k_for_checked(&self, tol: f64) -> Option<usize> {
        // exact entry
        if let Some(&k) = self.entries.get(&tol_key(tol)) {
            return Some(k);
        }
        // tightest calibrated tolerance that is <= requested tol is safe
        // (more iterations than strictly needed, never fewer).
        let mut best: Option<usize> = None;
        let mut best_tol = 0.0f64;
        for (&key, &k) in &self.entries {
            let t = f64::from_bits(key);
            if t <= tol && t > best_tol {
                best_tol = t;
                best = Some(k);
            }
        }
        best
    }

    /// The tightest tolerance the table was calibrated for (the lower
    /// bound of what [`Self::k_for_checked`] accepts); `None` for an
    /// uncalibrated [`Self::conservative`] table.
    pub fn tightest_calibrated(&self) -> Option<f64> {
        self.entries
            .keys()
            .map(|&k| f64::from_bits(k))
            .fold(None, |acc: Option<f64>, t| {
                Some(acc.map_or(t, |a| a.min(t)))
            })
    }

    /// Online correction: the executed batch at tolerance `tol` reported a
    /// residual above target → move that tolerance one rung up the ladder.
    pub fn bump(&mut self, tol: f64) {
        let cur = self.k_for(tol);
        let next = self
            .ladder
            .iter()
            .find(|&&k| k > cur)
            .copied()
            .unwrap_or(cur);
        self.entries.insert(tol_key(tol), next);
    }

    /// The ascending artifact iteration ladder.
    pub fn ladder(&self) -> &[usize] {
        &self.ladder
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geometric_trace(len: usize, rate: f64) -> Vec<f64> {
        (0..len).map(|i| rate.powi(i as i32)).collect()
    }

    #[test]
    fn calibrate_monotone_in_tol() {
        // step shrinks by 0.7 per iter: tighter tol → larger k
        let trace = geometric_trace(100, 0.7);
        let t = TruncationTable::calibrate(
            &[10, 20, 40, 80],
            &trace,
            &[1e-1, 1e-2, 1e-3, 1e-6],
        );
        let ks: Vec<usize> =
            [1e-1, 1e-2, 1e-3, 1e-6].iter().map(|&x| t.k_for(x)).collect();
        assert!(ks[0] <= ks[1] && ks[1] <= ks[2] && ks[2] <= ks[3], "{ks:?}");
        assert_eq!(ks[0], 10); // 0.7^7 < 0.1 → needs 8 iters → rung 10
        assert_eq!(ks[3], 40); // 0.7^39 ~ 9e-7 → rung 40
    }

    #[test]
    fn uncalibrated_tol_uses_safe_entry() {
        let trace = geometric_trace(100, 0.7);
        let t = TruncationTable::calibrate(
            &[10, 20, 40, 80],
            &trace,
            &[1e-2, 1e-4],
        );
        // 1e-3 not calibrated: must pick the 1e-4 entry (safe, tighter)
        assert_eq!(t.k_for(1e-3), t.k_for(1e-4));
        // 1e-1 not calibrated, nothing tighter→ k_for(1e-2) is <= tol? 1e-2<=1e-1 yes
        assert_eq!(t.k_for(1e-1), t.k_for(1e-2));
    }

    #[test]
    fn never_converging_trace_maps_to_max() {
        let trace = vec![1.0; 50];
        let t =
            TruncationTable::calibrate(&[10, 20, 40], &trace, &[1e-3]);
        assert_eq!(t.k_for(1e-3), 40);
    }

    #[test]
    fn bump_moves_up_ladder_and_saturates() {
        let trace = geometric_trace(100, 0.5);
        let mut t =
            TruncationTable::calibrate(&[10, 20, 40], &trace, &[1e-2]);
        let k0 = t.k_for(1e-2);
        t.bump(1e-2);
        let k1 = t.k_for(1e-2);
        assert!(k1 > k0);
        t.bump(1e-2);
        t.bump(1e-2);
        t.bump(1e-2);
        assert_eq!(t.k_for(1e-2), 40); // saturates at top rung
    }

    #[test]
    fn conservative_always_max() {
        let t = TruncationTable::conservative(&[10, 80, 40]);
        assert_eq!(t.k_for(1e-1), 80);
        assert_eq!(t.k_for(1e-9), 80);
    }

    #[test]
    fn checked_lookup_refuses_beyond_calibrated_range() {
        let trace = geometric_trace(100, 0.7);
        let t = TruncationTable::calibrate(
            &[10, 20, 40, 80],
            &trace,
            &[1e-1, 1e-4],
        );
        // calibrated and covered tolerances route normally
        assert_eq!(t.k_for_checked(1e-1), Some(t.k_for(1e-1)));
        assert_eq!(t.k_for_checked(1e-2), Some(t.k_for(1e-4)));
        assert_eq!(t.k_for_checked(5e-1), Some(t.k_for(1e-1)));
        // tighter than everything calibrated: refused, not clamped
        assert_eq!(t.k_for_checked(1e-9), None);
        // ... while the clamping lookup still serves the top rung
        assert_eq!(t.k_for(1e-9), 80);
        assert_eq!(t.tightest_calibrated(), Some(1e-4));
        let c = TruncationTable::conservative(&[10, 20]);
        assert_eq!(c.k_for_checked(1e-3), None);
        assert_eq!(c.tightest_calibrated(), None);
    }
}
