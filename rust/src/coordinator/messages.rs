//! Request/response types of the optimization-layer server.

use std::time::Instant;

/// A differentiation request against a registered layer.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    /// registered layer this request targets
    pub layer: String,
    /// per-request parameters θ
    pub q: Vec<f64>,
    pub b: Vec<f64>,
    pub h: Vec<f64>,
    /// requested truncation tolerance (paper §4.3) — the router maps this
    /// to an iteration count k via the calibrated truncation table.
    pub tol: f64,
    pub submitted: Instant,
}

/// The solved layer + gradient.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub x: Vec<f64>,
    /// ∂x/∂b, row-major (n × p)
    pub jx: Vec<f64>,
    /// primal residual reported by the executable
    pub prim_residual: f64,
    /// iterations the router selected
    pub k_used: usize,
    /// how many requests shared the executed batch
    pub batch_size: usize,
    /// end-to-end latency in seconds
    pub latency: f64,
    /// which backend served it ("pjrt" | "native")
    pub backend: &'static str,
}

/// Failure envelope (never panics across the channel boundary).
#[derive(Clone, Debug)]
pub struct Failure {
    pub id: u64,
    pub error: String,
}

/// What workers send back.
#[derive(Clone, Debug)]
pub enum Reply {
    Ok(Response),
    Err(Failure),
}

impl Reply {
    pub fn id(&self) -> u64 {
        match self {
            Reply::Ok(r) => r.id,
            Reply::Err(f) => f.id,
        }
    }
}
