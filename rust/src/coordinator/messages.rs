//! Request/response types of the optimization-layer server.

use std::time::{Duration, Instant};

use crate::obs::{StageSpans, StageStamps};

/// Request priority class. Under admission or queue pressure the
/// traffic plane sheds strictly in priority order — [`Priority::Low`]
/// sheds before [`Priority::Normal`] before [`Priority::High`] — by
/// giving each class a graduated slice of the relevant budget (see
/// `net::server` admission and the coordinator's shard queues). The
/// declaration order gives `High < Normal < Low`, so the derived `Ord`
/// sorts by *shedding preference* (greater = shed sooner).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    /// Latency-critical traffic: sheds last, full budgets.
    High,
    /// The default class (wire-compatible with pre-priority clients).
    #[default]
    Normal,
    /// Best-effort traffic: first to shed under pressure.
    Low,
}

impl Priority {
    /// Every class, in shedding order (High last).
    pub const ALL: [Priority; 3] =
        [Priority::High, Priority::Normal, Priority::Low];

    /// Stable wire tag (see `net::proto`).
    pub fn code(self) -> u8 {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    /// Inverse of [`Priority::code`]; `None` on an unknown tag (the
    /// codec maps that to a `Protocol` error, never a panic).
    pub fn from_code(c: u8) -> Option<Self> {
        match c {
            0 => Some(Priority::High),
            1 => Some(Priority::Normal),
            2 => Some(Priority::Low),
            _ => None,
        }
    }

    /// Metric-label form ("high" | "normal" | "low").
    pub fn label(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }

    /// Index into per-class counter arrays (== `code()` as usize).
    pub fn idx(self) -> usize {
        self.code() as usize
    }
}

/// A differentiation request against a registered layer.
#[derive(Clone, Debug)]
pub struct Request {
    /// Client-assigned correlation id.
    pub id: u64,
    /// registered layer this request targets
    pub layer: String,
    /// per-request parameter θ: objective linear term q
    pub q: Vec<f64>,
    /// per-request parameter θ: equality right-hand side b
    pub b: Vec<f64>,
    /// per-request parameter θ: inequality right-hand side h
    pub h: Vec<f64>,
    /// requested truncation tolerance (paper §4.3) — the router maps this
    /// to an iteration count k via the calibrated truncation table.
    pub tol: f64,
    /// Adjoint seed v = dL/dx* (length n). `Some` turns this into a
    /// *gradient* request: the worker answers with a
    /// [`GradientResponse`] carrying vᵀ∂x*/∂θ for every θ — the full
    /// Jacobian never crosses the channel. `None` is the classic solve
    /// request ([`Response`], which ships ∂x/∂b).
    pub grad_v: Option<Vec<f64>>,
    /// Optional warm-start session key. Requests sharing a session key
    /// share a slot in the coordinator's [`crate::warm::WarmStartCache`]
    /// (when one is configured): each solve's converged iterate seeds
    /// the session's next solve, however far θ drifted — subject only
    /// to the cache's staleness radius. `None` falls back to
    /// content-addressed fingerprinting (hits on exact θ repeats only).
    /// Remote callers set it per connection (see
    /// [`crate::net::PipelinedClient::set_session`]).
    pub session: Option<u64>,
    /// Priority class: decides shedding order under pressure (Low
    /// first), never execution order — admitted requests batch and
    /// execute identically whatever their class.
    pub priority: Priority,
    /// Optional per-request deadline budget in microseconds, measured
    /// from `submitted`. An expired request is shed with
    /// [`FailureKind::DeadlineExceeded`] at the next checkpoint
    /// (admission, batch formation, pre-execution) instead of consuming
    /// a solve — principled by the paper's truncation bound: work that
    /// can no longer be useful is dropped, work that can is untouched.
    /// `None` (the wire default) never expires.
    pub deadline_us: Option<u32>,
    /// submission timestamp (end-to-end latency accounting)
    pub submitted: Instant,
    /// Stage-stamp record (the tracing plane, see [`crate::obs`]).
    /// Disabled ([`StageStamps::off`], the default) unless the server
    /// runs with `Config::stamps` — every stamp site is then a no-op
    /// and replies stay byte-identical to the pre-tracing wire.
    pub stamps: StageStamps,
    /// Set at admission by the coordinator's 1-in-N
    /// [`crate::obs::TraceSampler`]: this request's solve records
    /// per-iteration residuals into the trace ring. Never set by
    /// clients; the wire has no bit for it.
    pub sampled: bool,
    /// Client asked the server to echo its stage breakdown on the
    /// reply (the opt-in wire extension — old servers reject frames
    /// carrying it, so clients only set it knowingly).
    pub echo_stages: bool,
}

impl Request {
    /// True when this is an adjoint (gradient) request.
    pub fn is_grad(&self) -> bool {
        self.grad_v.is_some()
    }

    /// True when the request's deadline budget has elapsed at `now`
    /// (always false without a deadline).
    pub fn expired_at(&self, now: Instant) -> bool {
        match self.deadline_us {
            Some(us) => {
                now.duration_since(self.submitted)
                    >= Duration::from_micros(us as u64)
            }
            None => false,
        }
    }

    /// [`Self::expired_at`] against `Instant::now()`.
    pub fn expired(&self) -> bool {
        self.expired_at(Instant::now())
    }
}

/// The solved layer + gradient.
#[derive(Clone, Debug)]
pub struct Response {
    /// Correlation id of the request this answers.
    pub id: u64,
    /// Primal minimizer x*.
    pub x: Vec<f64>,
    /// ∂x/∂b, row-major (n × p)
    pub jx: Vec<f64>,
    /// primal residual reported by the executable
    pub prim_residual: f64,
    /// iterations the router selected
    pub k_used: usize,
    /// how many requests shared the executed batch
    pub batch_size: usize,
    /// end-to-end latency in seconds
    pub latency: f64,
    /// which backend served it
    /// ("pjrt" | "native" | "native-sparse" | "native-admm")
    pub backend: &'static str,
    /// The request's stage stamps as of reply construction (server
    /// side only — never crosses the wire verbatim; the net front end
    /// adds the reply-written stamp and derives [`Response::stages`]).
    pub stamps: StageStamps,
    /// Server-side stage breakdown in µs, present on a decoded wire
    /// reply when the request set [`Request::echo_stages`] (and filled
    /// by the net front end just before encoding). `None` everywhere
    /// else — and `None` keeps the wire byte-identical to pre-tracing.
    pub stages: Option<StageSpans>,
}

/// The reply to a gradient ([`Request::grad_v`]) request: the solved
/// layer plus vᵀ∂x*/∂θ for every parameter — O(n+m+p) floats on the
/// wire where the solve path's Jacobian is O(n·d).
#[derive(Clone, Debug)]
pub struct GradientResponse {
    /// Correlation id of the request this answers.
    pub id: u64,
    /// Primal minimizer x*.
    pub x: Vec<f64>,
    /// vᵀ∂x*/∂q (length n).
    pub grad_q: Vec<f64>,
    /// vᵀ∂x*/∂b (length p).
    pub grad_b: Vec<f64>,
    /// vᵀ∂x*/∂h (length m).
    pub grad_h: Vec<f64>,
    /// primal feasibility residual of x*
    pub prim_residual: f64,
    /// iterations the router selected (forward and adjoint both run k)
    pub k_used: usize,
    /// how many requests shared the executed batch
    pub batch_size: usize,
    /// end-to-end latency in seconds
    pub latency: f64,
    /// which backend served it
    /// ("native" | "native-sparse" | "native-admm")
    pub backend: &'static str,
    /// Stage stamps as of reply construction (see [`Response::stamps`]).
    pub stamps: StageStamps,
    /// Echoed stage breakdown (see [`Response::stages`]).
    pub stages: Option<StageSpans>,
}

/// Machine-readable failure classification — clients (in particular the
/// wire protocol in [`crate::net`]) branch on this, never on the
/// human-readable message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailureKind {
    /// The request was malformed or unroutable (unknown layer, wrong θ
    /// dimensions, bad adjoint seed). Retrying unchanged will fail again.
    Invalid,
    /// Admission control shed the request: the serving front end was at
    /// its in-flight budget. Retrying after backoff is expected to work.
    Overloaded,
    /// The request was still queued when the coordinator (or the network
    /// front end) began a graceful shutdown.
    Shutdown,
    /// The solver/engine failed while executing the request's batch.
    Exec,
    /// The request's own deadline budget elapsed before execution; it
    /// was shed at an admission / batch-formation / pre-execution
    /// checkpoint without consuming a solve. Retrying is pointless at
    /// the same deadline — the caller's budget, not the server, decides.
    DeadlineExceeded,
}

impl FailureKind {
    /// Stable wire tag (see `net::proto`).
    pub fn code(self) -> u8 {
        match self {
            FailureKind::Invalid => 0,
            FailureKind::Overloaded => 1,
            FailureKind::Shutdown => 2,
            FailureKind::Exec => 3,
            FailureKind::DeadlineExceeded => 4,
        }
    }

    /// Inverse of [`FailureKind::code`]; `None` on an unknown tag.
    pub fn from_code(c: u8) -> Option<Self> {
        match c {
            0 => Some(FailureKind::Invalid),
            1 => Some(FailureKind::Overloaded),
            2 => Some(FailureKind::Shutdown),
            3 => Some(FailureKind::Exec),
            4 => Some(FailureKind::DeadlineExceeded),
            _ => None,
        }
    }
}

/// Failure envelope (never panics across the channel boundary).
#[derive(Clone, Debug)]
pub struct Failure {
    /// Correlation id of the failed request.
    pub id: u64,
    /// Machine-readable classification (retryable or not).
    pub kind: FailureKind,
    /// Human-readable failure description.
    pub error: String,
}

impl Failure {
    /// Convenience constructor.
    pub fn new(id: u64, kind: FailureKind, error: impl Into<String>) -> Self {
        Failure { id, kind, error: error.into() }
    }
}

/// What workers send back.
#[derive(Clone, Debug)]
pub enum Reply {
    /// The request was served.
    Ok(Response),
    /// A gradient request was served (adjoint path).
    Grad(GradientResponse),
    /// The request failed (routing, validation, or execution).
    Err(Failure),
}

impl Reply {
    /// Correlation id, whichever arm.
    pub fn id(&self) -> u64 {
        match self {
            Reply::Ok(r) => r.id,
            Reply::Grad(g) => g.id,
            Reply::Err(f) => f.id,
        }
    }

    /// Failure classification of an `Err` reply; `None` on success —
    /// reconciliation code (client-side shed/drain tallies vs server
    /// counters) branches on this instead of matching the envelope.
    pub fn failure_kind(&self) -> Option<FailureKind> {
        match self {
            Reply::Err(f) => Some(f.kind),
            _ => None,
        }
    }

    /// Mutable stage stamps of a served reply (`None` for failures,
    /// which carry no stamps). The net front end uses this to take the
    /// reply-written stamp just before encoding.
    pub fn stamps_mut(&mut self) -> Option<&mut StageStamps> {
        match self {
            Reply::Ok(r) => Some(&mut r.stamps),
            Reply::Grad(g) => Some(&mut g.stamps),
            Reply::Err(_) => None,
        }
    }

    /// Stage stamps of a served reply (`None` for failures).
    pub fn stamps(&self) -> Option<&StageStamps> {
        match self {
            Reply::Ok(r) => Some(&r.stamps),
            Reply::Grad(g) => Some(&g.stamps),
            Reply::Err(_) => None,
        }
    }

    /// Echoed stage breakdown of a decoded wire reply, whichever arm.
    pub fn stages(&self) -> Option<&StageSpans> {
        match self {
            Reply::Ok(r) => r.stages.as_ref(),
            Reply::Grad(g) => g.stages.as_ref(),
            Reply::Err(_) => None,
        }
    }

    /// Set the echoed stage breakdown on a served reply (no-op for
    /// failures). Used by the net front end at encode time.
    pub fn set_stages(&mut self, spans: StageSpans) {
        match self {
            Reply::Ok(r) => r.stages = Some(spans),
            Reply::Grad(g) => g.stages = Some(spans),
            Reply::Err(_) => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn failure_kind_codes_round_trip() {
        for k in [
            FailureKind::Invalid,
            FailureKind::Overloaded,
            FailureKind::Shutdown,
            FailureKind::Exec,
            FailureKind::DeadlineExceeded,
        ] {
            assert_eq!(FailureKind::from_code(k.code()), Some(k));
        }
        assert_eq!(FailureKind::from_code(200), None);
    }

    #[test]
    fn priority_codes_round_trip_and_order_by_shed_preference() {
        for p in Priority::ALL {
            assert_eq!(Priority::from_code(p.code()), Some(p));
            assert_eq!(p.idx(), p.code() as usize);
        }
        assert_eq!(Priority::from_code(3), None);
        assert_eq!(Priority::default(), Priority::Normal);
        // derived Ord sorts by shedding preference: Low sheds first
        assert!(Priority::High < Priority::Normal);
        assert!(Priority::Normal < Priority::Low);
        assert_eq!(Priority::Low.label(), "low");
        assert_eq!(Priority::High.label(), "high");
    }

    #[test]
    fn deadline_expiry_is_measured_from_submission() {
        let mk = |deadline_us| Request {
            id: 1,
            layer: "l".into(),
            q: vec![],
            b: vec![],
            h: vec![],
            tol: 1e-3,
            grad_v: None,
            session: None,
            priority: Priority::Normal,
            deadline_us,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        };
        let never = mk(None);
        let soon = mk(Some(50));
        let generous = mk(Some(60_000_000));
        let later = Instant::now() + Duration::from_millis(5);
        assert!(!never.expired_at(later));
        assert!(soon.expired_at(later));
        assert!(!generous.expired_at(later));
        assert!(!generous.expired());
    }

    #[test]
    fn reply_id_covers_every_arm() {
        let f = Failure::new(7, FailureKind::Overloaded, "busy");
        let r = Reply::Err(f);
        assert_eq!(r.id(), 7);
        assert_eq!(r.failure_kind(), Some(FailureKind::Overloaded));
    }
}
