//! The optimization-layer server: a sharded pool of
//! router → dynamic batcher → worker pipelines.
//!
//! Topology (std threads; tokio is unavailable offline and the workload is
//! CPU-bound anyway):
//!
//! ```text
//!   clients ──▶ shard_for(layer, session) ─┬─▶ shard 0 ─▶ workers ─▶ replies
//!                (FNV-1a; round-robin      ├─▶ shard 1 ─▶ workers ─▶   │
//!                 for session-less         └─▶ shard S ─▶ workers ─▶   │
//!                 requests)                     ▲ steal oldest batch ──┘
//! ```
//!
//! Each shard owns a **bounded** submit queue, a router thread with a
//! private [`Batcher`] (tol→k via the truncation table, batches keyed per
//! (layer, family, k, grad), flushed at `max_batch` or after
//! `batch_timeout_us`), and a slice of the worker pool. Formed batches
//! land on the shard's batch queue; an idle worker first drains its own
//! shard, then **steals the oldest batch from the deepest sibling** so
//! ragged load can't strand work behind one hot shard. Requests carrying
//! a session key always hash to the same shard, so warm-start locality
//! survives sharding; with `pin_cores` each worker additionally pins
//! itself to a CPU (best effort, see [`crate::util::affinity`]).
//!
//! Each worker owns its own PJRT [`Engine`] (the xla handles are not Send,
//! so engines are constructed *inside* the worker thread) and falls back
//! to the native **batched** Alt-Diff engine for layers without compiled
//! artifacts — one [`BatchedAltDiff`] launch per [`Batch`], never a
//! per-request solve loop.

use super::batcher::{Batch, Batcher};
use super::messages::{
    Failure, FailureKind, GradientResponse, Priority, Reply, Request,
    Response,
};
use super::metrics::Metrics;
use super::truncation::{EngineRouter, TruncationTable};
use crate::admm::{AdmmQp, AdmmSettings, BatchedAdmm};
use crate::altdiff::{
    BackwardMode, DenseAltDiff, Options, Param, SparseAltDiff,
};
use crate::batch::{
    BatchSolution, BatchVjp, BatchedAltDiff, BatchedSparseAltDiff,
};
use crate::error::{AltDiffError, Result};
use crate::fw::{BatchedFw, FwQp};
use crate::obs::{
    IterObserver, Stage, StageStamps, TraceCollector, TraceEvent,
    TraceRing, TraceSampler,
};
use crate::prob::{Qp, SparseQp};
use crate::runtime::Engine;
use crate::warm::{
    fingerprint, AdjointSeed, AdmmSeed, EngineFamily, EngineSeed,
    FwSeed, WarmStart, WarmStartCache,
};
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Which execution engines back a registered layer.
pub enum LayerEngine {
    /// Dense QP layer: PJRT-eligible, with the native dense batch engine
    /// as fallback/oracle.
    Dense {
        /// native engine (calibration + parity checks + residuals)
        solver: DenseAltDiff,
        /// native batched engine (fallback execution path; shares the
        /// solver's registration-time factorization)
        batched: BatchedAltDiff,
        /// H⁻¹ artifact input, precomputed at registration (f32 contract)
        hinv_f32: Vec<f32>,
        /// A artifact input
        a_f32: Vec<f32>,
        /// G artifact input
        g_f32: Vec<f32>,
        /// batch sizes available in the compiled family (empty → native
        /// only)
        batches: Vec<usize>,
    },
    /// Sparse QP layer (Table 4 regime): no compiled family — every
    /// batch is one [`BatchedSparseAltDiff`] launch.
    Sparse {
        /// sequential engine (calibration + residual reporting)
        solver: SparseAltDiff,
        /// batched engine sharing the solver's registration
        batched: BatchedSparseAltDiff,
    },
    /// Dense QP layer served exclusively by the ADMM engine family
    /// (registered via [`CoordinatorBuilder::register_admm`]): no
    /// compiled family — every batch is one [`BatchedAdmm`] launch.
    Admm {
        /// single-problem engine (calibration + residual reporting)
        solver: AdmmQp,
        /// batched engine sharing the solver's factorization caches
        batched: BatchedAdmm,
    },
    /// Vertex-enumerable QP layer served exclusively by the
    /// projection-free Frank–Wolfe family (registered via
    /// [`CoordinatorBuilder::register_fw`]): no compiled family — every
    /// batch is one [`BatchedFw`] launch.
    Fw {
        /// single-problem engine (calibration + residual reporting)
        solver: FwQp,
        /// batched engine sharing the solver's registration
        batched: BatchedFw,
    },
}

/// The ADMM engine pair a routed multi-family layer keeps *next to* its
/// Alt-Diff engines (see [`CoordinatorBuilder::register_routed`]).
pub struct AdmmEngines {
    /// single-problem engine (probes + residual reporting)
    pub solver: AdmmQp,
    /// batched engine sharing the solver's factorization caches
    pub batched: BatchedAdmm,
}

/// The Frank–Wolfe engine pair a routed multi-family layer keeps next
/// to its Alt-Diff engines — present only when the layer's feasible set
/// is FW-servable ([`crate::fw::FeasibleSet::detect`]).
pub struct FwEngines {
    /// single-problem engine (probes + residual reporting)
    pub solver: FwQp,
    /// batched engine sharing the solver's registration
    pub batched: BatchedFw,
}

/// A layer registered with the server (immutable after startup, shared
/// across workers).
pub struct RegisteredLayer {
    /// Registration name (routing key).
    pub name: String,
    /// Variables n.
    pub n: usize,
    /// Inequality constraints m.
    pub m: usize,
    /// Equality constraints p.
    pub p: usize,
    /// ADMM penalty ρ.
    pub rho: f64,
    /// The execution engines backing this layer.
    pub engine: LayerEngine,
    /// The second engine family, present on routed layers (the
    /// cross-method router dispatches each batch to `engine` or here).
    pub admm: Option<AdmmEngines>,
    /// The third engine family, present on routed layers whose feasible
    /// set is FW-servable (box/simplex/ℓ1 ball).
    pub fw: Option<FwEngines>,
    /// Cross-method routing table, present when the families were
    /// probed at registration ([`CoordinatorBuilder::register_routed`]);
    /// absent layers route per [`Self::family`] through `table`.
    pub router: Option<EngineRouter>,
    /// tol → k router table (Mutex: workers bump it online)
    pub table: Mutex<TruncationTable>,
}

impl RegisteredLayer {
    /// The engine family a non-routed layer serves with.
    pub fn family(&self) -> EngineFamily {
        match self.engine {
            LayerEngine::Admm { .. } => EngineFamily::Admm,
            LayerEngine::Fw { .. } => EngineFamily::Fw,
            _ => EngineFamily::AltDiff,
        }
    }

    /// The ADMM engine pair, wherever it lives (primary engine for
    /// [`LayerEngine::Admm`] layers, the sidecar for routed layers).
    pub fn admm_engines(&self) -> Option<(&AdmmQp, &BatchedAdmm)> {
        match &self.engine {
            LayerEngine::Admm { solver, batched } => {
                Some((solver, batched))
            }
            _ => self.admm.as_ref().map(|e| (&e.solver, &e.batched)),
        }
    }

    /// The Frank–Wolfe engine pair, wherever it lives (primary engine
    /// for [`LayerEngine::Fw`] layers, the sidecar for routed layers).
    pub fn fw_engines(&self) -> Option<(&FwQp, &BatchedFw)> {
        match &self.engine {
            LayerEngine::Fw { solver, batched } => Some((solver, batched)),
            _ => self.fw.as_ref().map(|e| (&e.solver, &e.batched)),
        }
    }
}

/// Server configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Worker threads across the whole pool (each owns its own PJRT
    /// engine). Distributed round-robin over the shards; effectively
    /// raised to `shards` so every shard keeps at least one worker.
    pub workers: usize,
    /// Dynamic-batcher flush threshold.
    pub max_batch: usize,
    /// Deadline-aware batching knob (microseconds): a partial batch
    /// flushes when its oldest request has waited this long, instead of
    /// holding out for `max_batch` occupancy. 0 clamps to 1µs
    /// (flush-on-next-pass). The flush reason is invisible to the
    /// exact-k contract — a timeout-flushed batch runs the same routed
    /// k as a full one.
    pub batch_timeout_us: u64,
    /// Coordinator shards. Each shard owns a bounded submit queue, a
    /// router thread with a private batcher, and a slice of the worker
    /// pool; requests hash to shards by (layer, session) so warm-start
    /// locality survives sharding. 1 (the default) reproduces the
    /// single-dispatcher topology.
    pub shards: usize,
    /// Per-shard backlog bound, in requests. The submit queue sheds
    /// (`FailureKind::Overloaded`) once the shard already holds this
    /// many unserved requests; the shard router additionally pauses
    /// draining while its formed-batch backlog is at the bound, so the
    /// bound covers queued *and* batched-but-unexecuted work.
    pub shard_queue: usize,
    /// Pin each worker thread to a CPU (`worker_index % cores`), best
    /// effort — placement only, never correctness (see
    /// [`crate::util::affinity::pin_current_thread`]).
    pub pin_cores: bool,
    /// artifact directory; None → native backend only
    pub artifacts: Option<PathBuf>,
    /// calibration tolerances for new layers
    pub calib_tols: Vec<f64>,
    /// Warm-start cache capacity (entries across all layers); 0
    /// disables the cache entirely — the default, so serving keeps the
    /// cold fixed-k contract unless an operator opts in. When enabled,
    /// workers consult the cache before every native batched launch
    /// (keyed by layer, routed k, and the request's session key or θ
    /// fingerprint) and write converged iterates back after; solve
    /// batches still run exactly k iterations (warm ⇒ better accuracy
    /// at the same cost, and forward-mode Jacobians stay valid), while
    /// gradient batches with warm members may stop early per element at
    /// the batch's tightest requested tolerance (`warm_iters_saved`).
    pub warm_capacity: usize,
    /// Warm-start staleness radius: a cached iterate is only reused
    /// when the requesting θ is within this relative distance of the θ
    /// it was solved at (see [`crate::warm::theta_distance`]).
    pub warm_radius: f64,
    /// Stage-stamp tracing (the [`crate::obs`] plane). Off by default:
    /// every request then carries an inert [`StageStamps::off`] record,
    /// stamp sites cost one predictable branch, nothing extra is
    /// counted, and replies stay byte-identical to the pre-tracing
    /// wire. On, each request is stamped at every handoff and the
    /// per-(stage × class) histograms fill.
    pub stamps: bool,
    /// Deep-trace sampling period: every N-th admitted request records
    /// per-iteration solver residuals into the trace ring. 0 (the
    /// default) disables sampling — engines run with no observer.
    pub trace_every: u64,
    /// Trace ring capacity in events (see [`TraceRing::new`] for
    /// stripe rounding). Only consulted when `trace_every > 0`.
    pub trace_ring: usize,
    /// Sampler phase seed, so co-located servers don't all trace the
    /// same ordinal positions ([`TraceSampler::new`]).
    pub trace_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            workers: 2,
            max_batch: 8,
            batch_timeout_us: 2_000,
            shards: 1,
            shard_queue: 1024,
            pin_cores: false,
            artifacts: None,
            calib_tols: vec![1e-1, 1e-2, 1e-3, 1e-4],
            warm_capacity: 0,
            warm_radius: 0.5,
            stamps: false,
            trace_every: 0,
            trace_ring: 256,
            trace_seed: 0,
        }
    }
}

/// Deterministic shard routing: FNV-1a over the layer name and the
/// session key, mod `shards`. Requests sharing (layer, session) always
/// land on the same shard, so a warm-start session's cache entry is
/// only ever raced by its own shard's workers. Exposed so tests and
/// operators debugging a hot shard can predict placement.
pub fn shard_for(layer: &str, session: u64, shards: usize) -> usize {
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut hash = FNV_OFFSET;
    for &byte in layer.as_bytes() {
        hash ^= byte as u64;
        hash = hash.wrapping_mul(FNV_PRIME);
    }
    for byte in session.to_le_bytes() {
        hash ^= byte as u64;
        hash = hash.wrapping_mul(FNV_PRIME);
    }
    (hash % shards.max(1) as u64) as usize
}

/// Graduated per-class share of a bounded admission/backlog budget:
/// High keeps the full budget, Normal forfeits 1/8, Low forfeits 1/4 —
/// so as pressure rises Low sheds strictly before Normal before High
/// (the last budget slots are reserved for higher classes), while
/// execution order for *admitted* requests is untouched. Tiny budgets
/// (< 4) collapse to equal shares rather than starving a class
/// outright, which also keeps single-slot test configurations
/// class-blind. Used by both the coordinator's [`ShardQueue`]s and the
/// network front end's in-flight admission gate.
pub fn class_budget(max: usize, p: Priority) -> usize {
    let forfeit = match p {
        Priority::High => 0,
        Priority::Normal => max / 8,
        Priority::Low => max / 4,
    };
    max.saturating_sub(forfeit).max(1)
}

/// What [`ShardQueue::push`] did with a request.
enum PushOutcome {
    /// Accepted; the shard router will route it.
    Queued,
    /// The shard is at its backlog bound — shed (Overloaded).
    Full,
    /// A graceful drain is underway — reject (Shutdown).
    Draining,
}

struct ShardQueueState {
    q: std::collections::VecDeque<Request>,
    shutdown: bool,
}

/// One shard's bounded submit queue (clients push, the shard's router
/// thread drains). Mutex + Condvar: the router parks here between
/// arrivals, bounded by its batcher's next flush deadline.
struct ShardQueue {
    state: Mutex<ShardQueueState>,
    cv: std::sync::Condvar,
    cap: usize,
}

impl ShardQueue {
    fn new(cap: usize) -> Self {
        ShardQueue {
            state: Mutex::new(ShardQueueState {
                q: std::collections::VecDeque::new(),
                shutdown: false,
            }),
            cv: std::sync::Condvar::new(),
            cap: cap.max(1),
        }
    }

    fn push(&self, req: Request) -> PushOutcome {
        let mut st = self.state.lock().unwrap();
        if st.shutdown {
            return PushOutcome::Draining;
        }
        // priority-ordered shedding: each class sees a graduated slice
        // of the backlog bound, so Low overflows first, then Normal,
        // then High — strictly ordered at equal arrival pressure
        if st.q.len() >= class_budget(self.cap, req.priority) {
            return PushOutcome::Full;
        }
        st.q.push_back(req);
        drop(st);
        self.cv.notify_one();
        PushOutcome::Queued
    }

    /// Block up to `timeout` for arrivals, then drain the burst (batches
    /// only form if concurrent arrivals are routed together — same
    /// rationale as the old dispatcher's recv-then-try_recv drain).
    /// Returns the drained requests and the shutdown flag.
    fn pop_all(&self, timeout: Duration) -> (Vec<Request>, bool) {
        let mut st = self.state.lock().unwrap();
        if st.q.is_empty() && !st.shutdown {
            let (guard, _) = self.cv.wait_timeout(st, timeout).unwrap();
            st = guard;
        }
        (st.q.drain(..).collect(), st.shutdown)
    }

    /// Shutdown flag without draining (used while the router is paused
    /// on formed-batch backpressure).
    fn is_shutdown(&self) -> bool {
        self.state.lock().unwrap().shutdown
    }

    fn len(&self) -> usize {
        self.state.lock().unwrap().q.len()
    }

    fn begin_shutdown(&self) {
        let mut st = self.state.lock().unwrap();
        st.shutdown = true;
        drop(st);
        self.cv.notify_all();
    }
}

/// One shard's queue of *formed* batches (router pushes, the shard's
/// workers pop, idle sibling workers steal). `elems`/`closed` are
/// atomics so stealers and the router's backpressure check can peek
/// without taking the lock.
struct BatchQueue {
    state: Mutex<std::collections::VecDeque<Batch>>,
    cv: std::sync::Condvar,
    depth: std::sync::atomic::AtomicUsize,
    elems: std::sync::atomic::AtomicUsize,
    closed: std::sync::atomic::AtomicBool,
}

impl BatchQueue {
    fn new() -> Self {
        BatchQueue {
            state: Mutex::new(std::collections::VecDeque::new()),
            cv: std::sync::Condvar::new(),
            depth: std::sync::atomic::AtomicUsize::new(0),
            elems: std::sync::atomic::AtomicUsize::new(0),
            closed: std::sync::atomic::AtomicBool::new(false),
        }
    }

    fn push(&self, b: Batch) {
        use std::sync::atomic::Ordering;
        let add = b.requests.len();
        let mut q = self.state.lock().unwrap();
        q.push_back(b);
        self.depth.store(q.len(), Ordering::Release);
        self.elems.fetch_add(add, Ordering::Release);
        drop(q);
        self.cv.notify_one();
    }

    /// Pop the oldest batch, waiting up to `timeout` when open+empty.
    /// Returns immediately (None) when closed+empty.
    fn pop_wait(&self, timeout: Duration) -> Option<Batch> {
        use std::sync::atomic::Ordering;
        let mut q = self.state.lock().unwrap();
        if q.is_empty() && !self.closed.load(Ordering::Acquire) {
            let (guard, _) = self.cv.wait_timeout(q, timeout).unwrap();
            q = guard;
        }
        let b = q.pop_front();
        self.depth.store(q.len(), Ordering::Release);
        if let Some(batch) = &b {
            self.elems
                .fetch_sub(batch.requests.len(), Ordering::Release);
        }
        b
    }

    /// Nonblocking steal of the oldest batch; `None` when empty or when
    /// the owner currently holds the lock (the thief just retries its
    /// next idle cycle instead of contending).
    fn try_steal(&self) -> Option<Batch> {
        use std::sync::atomic::Ordering;
        if self.depth.load(Ordering::Acquire) == 0 {
            return None;
        }
        let mut q = self.state.try_lock().ok()?;
        let b = q.pop_front();
        self.depth.store(q.len(), Ordering::Release);
        if let Some(batch) = &b {
            self.elems
                .fetch_sub(batch.requests.len(), Ordering::Release);
        }
        b
    }

    fn depth_batches(&self) -> usize {
        self.depth.load(std::sync::atomic::Ordering::Acquire)
    }

    fn depth_elems(&self) -> usize {
        self.elems.load(std::sync::atomic::Ordering::Acquire)
    }

    /// Router is done (drain complete): wake every parked worker.
    fn close(&self) {
        self.closed.store(true, std::sync::atomic::Ordering::Release);
        let _guard = self.state.lock().unwrap();
        self.cv.notify_all();
    }

    fn drained(&self) -> bool {
        use std::sync::atomic::Ordering;
        self.closed.load(Ordering::Acquire)
            && self.depth.load(Ordering::Acquire) == 0
    }
}

/// Handle to a running coordinator.
pub struct Coordinator {
    queues: Arc<Vec<ShardQueue>>,
    /// Kept so shed/drain replies can be issued at submit time; dropped
    /// at the end of [`Self::shutdown`] so `recv` disconnects once every
    /// buffered reply is consumed.
    reply_tx: Option<Sender<Reply>>,
    reply_rx: Receiver<Reply>,
    /// Shared serving metrics (live; read any time).
    pub metrics: Arc<Metrics>,
    routers: Vec<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    ready: Arc<std::sync::atomic::AtomicUsize>,
    n_workers: usize,
    next_id: u64,
    /// Round-robin cursor for session-less requests.
    rr: u64,
    layer_dims: Vec<(String, usize, usize, usize)>,
    /// [`Config::stamps`]: in-process submissions get enabled stamp
    /// records at admission when set.
    stamps_on: bool,
    /// 1-in-N deep-trace sampler ([`Config::trace_every`]).
    sampler: Arc<TraceSampler>,
    /// Finished solver traces, drained by `GET /trace`.
    ring: Arc<TraceRing>,
}

/// Builder: register layers, then start.
pub struct CoordinatorBuilder {
    config: Config,
    layers: BTreeMap<String, Arc<RegisteredLayer>>,
    ladder: Vec<usize>,
}

impl CoordinatorBuilder {
    /// Empty builder over the given configuration.
    pub fn new(config: Config) -> Self {
        CoordinatorBuilder {
            config,
            layers: BTreeMap::new(),
            // must match python/compile/aot.py ITERS
            ladder: vec![10, 20, 40, 80],
        }
    }

    /// Override the artifact iteration ladder (must match the manifest).
    pub fn ladder(mut self, ladder: Vec<usize>) -> Self {
        self.ladder = ladder;
        self
    }

    /// Calibrate a truncation table from a convergence trace against the
    /// builder's ladder and tolerance grid.
    fn calibrate(&self, trace: &[f64]) -> TruncationTable {
        TruncationTable::calibrate(
            &self.ladder,
            trace,
            &self.config.calib_tols,
        )
    }

    /// Iteration budget for the calibration solve (generous multiple of
    /// the top ladder rung).
    fn calib_iters(&self) -> usize {
        *self.ladder.last().unwrap_or(&80) * 4
    }

    /// Register a dense QP layer: factors H, precomputes the f32 artifact
    /// inputs, and calibrates the truncation table on the layer's own
    /// registered parameters.
    pub fn register(mut self, name: &str, qp: Qp, rho: f64) -> Result<Self> {
        let n = qp.n();
        let m = qp.m_ineq();
        let p = qp.p_eq();
        let solver = DenseAltDiff::new(qp, rho)?;
        let hinv = solver.hinv();
        // calibration trace on the registered θ
        let sol = solver.solve(&Options {
            tol: 1e-9,
            max_iter: self.calib_iters(),
            backward: BackwardMode::None,
            trace: true,
            ..Default::default()
        });
        let trace: Vec<f64> =
            sol.trace.iter().map(|t| t.step_rel).collect();
        let table = self.calibrate(&trace);
        // compiled family available?
        let batches = match &self.config.artifacts {
            Some(dir) => match crate::runtime::Manifest::load(dir) {
                Ok(man) => {
                    let mut bs: Vec<usize> = man
                        .variants
                        .iter()
                        .filter(|v| v.n == n && v.m == m && v.p == p)
                        .map(|v| v.batch)
                        .collect();
                    bs.sort_unstable();
                    bs.dedup();
                    bs
                }
                Err(_) => vec![],
            },
            None => vec![],
        };
        let a_f32 = solver.qp.a.to_f32();
        let g_f32 = solver.qp.g.to_f32();
        let batched = BatchedAltDiff::from_dense(&solver);
        let layer = RegisteredLayer {
            name: name.to_string(),
            n,
            m,
            p,
            rho,
            engine: LayerEngine::Dense {
                hinv_f32: hinv.to_f32(),
                a_f32,
                g_f32,
                solver,
                batched,
                batches,
            },
            admm: None,
            fw: None,
            router: None,
            table: Mutex::new(table),
        };
        self.layers.insert(name.to_string(), Arc::new(layer));
        Ok(self)
    }

    /// Register a sparse QP layer (Table 4 regime: diagonal P, CSR
    /// constraints). No compiled family exists for sparse layers — every
    /// dispatched batch becomes one [`BatchedSparseAltDiff`] launch on
    /// the native path, with the same tol→k routing as dense layers.
    pub fn register_sparse(
        mut self,
        name: &str,
        qp: SparseQp,
        rho: f64,
    ) -> Result<Self> {
        let n = qp.n();
        let m = qp.m_ineq();
        let p = qp.p_eq();
        let solver = SparseAltDiff::new(qp, rho)?;
        let sol = solver.solve(&Options {
            tol: 1e-9,
            max_iter: self.calib_iters(),
            backward: BackwardMode::None,
            trace: true,
            ..Default::default()
        });
        let trace: Vec<f64> =
            sol.trace.iter().map(|t| t.step_rel).collect();
        let table = self.calibrate(&trace);
        let batched = BatchedSparseAltDiff::from_sparse(&solver);
        let layer = RegisteredLayer {
            name: name.to_string(),
            n,
            m,
            p,
            rho,
            engine: LayerEngine::Sparse { solver, batched },
            admm: None,
            fw: None,
            router: None,
            table: Mutex::new(table),
        };
        self.layers.insert(name.to_string(), Arc::new(layer));
        Ok(self)
    }

    /// Register a dense QP layer served exclusively by the ADMM engine
    /// family: ρ is residual-balanced once at registration
    /// ([`AdmmQp::new_adapted`]), the truncation table is calibrated
    /// from the ADMM convergence trace, and every dispatched batch
    /// becomes one [`BatchedAdmm`] launch (backend `"native-admm"`).
    pub fn register_admm(
        mut self,
        name: &str,
        qp: Qp,
        rho: f64,
    ) -> Result<Self> {
        let n = qp.n();
        let m = qp.m_ineq();
        let p = qp.p_eq();
        let solver =
            AdmmQp::new_adapted(qp, rho, AdmmSettings::default())?;
        let sol = solver.solve(&Options {
            tol: 1e-9,
            max_iter: self.calib_iters(),
            backward: BackwardMode::None,
            trace: true,
            ..Default::default()
        });
        let trace: Vec<f64> =
            sol.trace.iter().map(|t| t.step_rel).collect();
        let table = self.calibrate(&trace);
        let batched = BatchedAdmm::from_single(&solver);
        let layer = RegisteredLayer {
            name: name.to_string(),
            n,
            m,
            p,
            rho: solver.rho,
            engine: LayerEngine::Admm { solver, batched },
            admm: None,
            fw: None,
            router: None,
            table: Mutex::new(table),
        };
        self.layers.insert(name.to_string(), Arc::new(layer));
        Ok(self)
    }

    /// Register a dense QP layer served exclusively by the Frank–Wolfe
    /// engine family: the constraint block must encode one of the
    /// servable LMO structures (box / simplex / ℓ1 ball — see
    /// [`crate::fw::FeasibleSet`]), the truncation table is calibrated
    /// from the FW convergence trace, and every dispatched batch
    /// becomes one [`BatchedFw`] launch (backend `"native-fw"`).
    pub fn register_fw(
        mut self,
        name: &str,
        qp: Qp,
        rho: f64,
    ) -> Result<Self> {
        let n = qp.n();
        let m = qp.m_ineq();
        let p = qp.p_eq();
        let solver = FwQp::new(qp, rho)?;
        let sol = solver.solve(&Options {
            tol: 1e-9,
            max_iter: self.calib_iters(),
            backward: BackwardMode::None,
            trace: true,
            ..Default::default()
        });
        let trace: Vec<f64> =
            sol.trace.iter().map(|t| t.step_rel).collect();
        let table = self.calibrate(&trace);
        let batched = BatchedFw::from_single(&solver);
        let layer = RegisteredLayer {
            name: name.to_string(),
            n,
            m,
            p,
            rho,
            engine: LayerEngine::Fw { solver, batched },
            admm: None,
            fw: None,
            router: None,
            table: Mutex::new(table),
        };
        self.layers.insert(name.to_string(), Arc::new(layer));
        Ok(self)
    }

    /// Register a dense QP layer behind the cross-method router: every
    /// servable engine family is built (Alt-Diff exactly as
    /// [`Self::register`], ADMM with registration-time ρ balancing, and
    /// Frank–Wolfe whenever the constraint block matches a servable LMO
    /// structure), each probes the registered θ with fixed-k solves at
    /// every ladder rung, and the per-tolerance winner table
    /// ([`EngineRouter`]) decides which family serves each subsequent
    /// batch. The compiled PJRT family remains available for
    /// Alt-Diff-routed batches only.
    pub fn register_routed(
        self,
        name: &str,
        qp: Qp,
        rho: f64,
    ) -> Result<Self> {
        let admm_qp = qp.clone();
        let fw_qp = qp.clone();
        let mut this = self.register(name, qp, rho)?;
        let layer = this.layers.remove(name).expect("just registered");
        let layer =
            Arc::into_inner(layer).expect("single-owner at build time");
        let admm_solver =
            AdmmQp::new_adapted(admm_qp, rho, AdmmSettings::default())?;
        let LayerEngine::Dense { solver, .. } = &layer.engine else {
            unreachable!("register() builds a Dense layer");
        };
        // conditioning probe: (max ℓᵢᵢ / min ℓᵢᵢ)² of the registration
        // Cholesky of H(ρ) — a cheap spectral-range proxy
        let diag: Vec<f64> =
            (0..layer.n).map(|i| solver.chol.l[(i, i)]).collect();
        let dmax = diag.iter().cloned().fold(f64::MIN, f64::max);
        let dmin = diag.iter().cloned().fold(f64::MAX, f64::min);
        let cond = (dmax / dmin.max(f64::MIN_POSITIVE)).powi(2);
        // FW is only probed when the constraint block encodes a
        // servable LMO structure; otherwise the router sees two
        // families, exactly as before FW existed.
        let fw_solver = FwQp::new(fw_qp, rho).ok();
        // residual-anchored rung probes on the registered θ, per family
        let mut alt_res = Vec::with_capacity(this.ladder.len());
        let mut admm_res = Vec::with_capacity(this.ladder.len());
        let mut fw_res = Vec::with_capacity(this.ladder.len());
        for &kk in &this.ladder {
            let popts = Options {
                tol: 0.0,
                max_iter: kk,
                backward: BackwardMode::None,
                rho,
                trace: false,
            };
            let sa = solver.solve(&popts);
            alt_res
                .push(solver.qp.kkt_residual(&sa.x, &sa.lam, &sa.nu));
            let sm = admm_solver.solve(&popts);
            admm_res.push(
                admm_solver.qp.kkt_residual(&sm.x, &sm.lam, &sm.nu),
            );
            if let Some(fs) = &fw_solver {
                let sf = fs.solve(&popts);
                fw_res
                    .push(fs.qp.kkt_residual(&sf.x, &sf.lam, &sf.nu));
            }
        }
        // probe order is the tie-break order: Alt-Diff keeps ties (the
        // paper's method), FW beats ADMM on equal residuals (no
        // projection, no factorization per iteration).
        let mut probes: Vec<(EngineFamily, &[f64])> =
            vec![(EngineFamily::AltDiff, alt_res.as_slice())];
        if fw_solver.is_some() {
            probes.push((EngineFamily::Fw, fw_res.as_slice()));
        }
        probes.push((EngineFamily::Admm, admm_res.as_slice()));
        let router = EngineRouter::from_family_probes(
            &this.ladder,
            &probes,
            &this.config.calib_tols,
            cond,
            (layer.n, layer.m, layer.p),
        );
        let admm_batched = BatchedAdmm::from_single(&admm_solver);
        let fw = fw_solver.map(|solver| {
            let batched = BatchedFw::from_single(&solver);
            FwEngines { solver, batched }
        });
        let layer = RegisteredLayer {
            admm: Some(AdmmEngines {
                solver: admm_solver,
                batched: admm_batched,
            }),
            fw,
            router: Some(router),
            ..layer
        };
        this.layers.insert(name.to_string(), Arc::new(layer));
        Ok(this)
    }

    /// Start the shard pool: one router thread + a slice of the worker
    /// pool per shard.
    pub fn start(self) -> Coordinator {
        let shards = self.config.shards.max(1);
        let metrics = Arc::new(Metrics::for_shards(shards));
        let layer_dims: Vec<(String, usize, usize, usize)> = self
            .layers
            .values()
            .map(|l| (l.name.clone(), l.n, l.m, l.p))
            .collect();
        let (reply_tx, reply_rx) = channel::<Reply>();

        // shared warm-start cache (None when disabled): workers consult
        // it before each native batched launch and write back after.
        // One Arc<Mutex> across ALL shards — session-hashed routing
        // means a session's entry is only contended by its own shard,
        // but the cache itself must stay correct even when stolen
        // batches touch it from a sibling's worker (it is: every access
        // holds the one lock for the whole batch lookup/writeback).
        let warm: Option<Arc<Mutex<WarmStartCache>>> =
            (self.config.warm_capacity > 0).then(|| {
                Arc::new(Mutex::new(WarmStartCache::new(
                    self.config.warm_capacity,
                    self.config.warm_radius,
                )))
            });

        let queues: Arc<Vec<ShardQueue>> = Arc::new(
            (0..shards)
                .map(|_| ShardQueue::new(self.config.shard_queue))
                .collect(),
        );
        let bqueues: Arc<Vec<BatchQueue>> =
            Arc::new((0..shards).map(|_| BatchQueue::new()).collect());

        // tracing plane: the sampler decides at admission, workers push
        // finished traces into the ring, `GET /trace` drains it
        let sampler = Arc::new(TraceSampler::new(
            self.config.trace_every,
            self.config.trace_seed,
        ));
        let ring = Arc::new(TraceRing::new(self.config.trace_ring));

        // workers, distributed round-robin over the shards (≥ 1 each)
        let ready = Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let total_workers = self.config.workers.max(1).max(shards);
        let cores = crate::util::affinity::available_cores();
        let mut workers = Vec::new();
        let mut global_idx = 0usize;
        for sidx in 0..shards {
            let per_shard = total_workers / shards
                + usize::from(sidx < total_workers % shards);
            for widx in 0..per_shard {
                let pin = self
                    .config
                    .pin_cores
                    .then_some(global_idx % cores);
                global_idx += 1;
                let bqueues = bqueues.clone();
                let layers = self.layers.clone();
                let reply_tx = reply_tx.clone();
                let metrics = metrics.clone();
                let artifacts = self.config.artifacts.clone();
                let ready = ready.clone();
                let warm = warm.clone();
                let ring = ring.clone();
                workers.push(
                    std::thread::Builder::new()
                        .name(format!("altdiff-worker-s{sidx}-{widx}"))
                        .spawn(move || {
                            shard_worker_loop(
                                sidx, bqueues, layers, reply_tx,
                                metrics, artifacts, ready, warm, pin,
                                ring,
                            )
                        })
                        .expect("spawn worker"),
                );
            }
        }
        let n_workers = global_idx;

        // shard routers
        let mut routers = Vec::new();
        for sidx in 0..shards {
            let queues = queues.clone();
            let bqueues = bqueues.clone();
            let layers = self.layers.clone();
            let config = self.config.clone();
            let metrics = metrics.clone();
            let reply_tx = reply_tx.clone();
            routers.push(
                std::thread::Builder::new()
                    .name(format!("altdiff-shard-{sidx}"))
                    .spawn(move || {
                        shard_router_loop(
                            sidx, queues, bqueues, layers, config,
                            metrics, reply_tx,
                        )
                    })
                    .expect("spawn shard router"),
            );
        }

        Coordinator {
            queues,
            reply_tx: Some(reply_tx),
            reply_rx,
            metrics,
            routers,
            workers,
            ready,
            n_workers,
            next_id: 0,
            rr: 0,
            layer_dims,
            stamps_on: self.config.stamps,
            sampler,
            ring,
        }
    }
}

/// Validate + route one request: `Some((family, k, req))` when it can
/// join a batch; `None` after an `Invalid` failure reply was sent. The
/// routing logic is shard-independent — every shard router runs this
/// exact path, which is what makes shard-pool results reproduce the
/// single-dispatcher results (same table, same checked lookups).
fn route_one(
    req: Request,
    layers: &BTreeMap<String, Arc<RegisteredLayer>>,
    metrics: &Metrics,
    reply_tx: &Sender<Reply>,
) -> Option<(EngineFamily, usize, Request)> {
    let Some(layer) = layers.get(&req.layer) else {
        metrics
            .failures
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let _ = reply_tx.send(Reply::Err(Failure {
            id: req.id,
            kind: FailureKind::Invalid,
            error: format!("unknown layer '{}'", req.layer),
        }));
        return None;
    };
    // validate θ dimensions here so a malformed request becomes a
    // Failure reply instead of panicking the worker's batched launch
    // (and taking its whole batch down with it)
    let bad_v = req
        .grad_v
        .as_ref()
        .map(|v| v.len() != layer.n)
        .unwrap_or(false);
    if req.q.len() != layer.n
        || req.b.len() != layer.p
        || req.h.len() != layer.m
        || bad_v
    {
        metrics
            .failures
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let _ = reply_tx.send(Reply::Err(Failure {
            id: req.id,
            kind: FailureKind::Invalid,
            error: format!(
                "bad θ/v dims for layer '{}': q={} b={} h={} v={:?}, \
                 want n={} p={} m={}",
                req.layer,
                req.q.len(),
                req.b.len(),
                req.h.len(),
                req.grad_v.as_ref().map(|v| v.len()),
                layer.n,
                layer.p,
                layer.m
            ),
        }));
        return None;
    }
    // routed via the *checked* lookup: a tolerance tighter than
    // everything the layer's table was calibrated for has no rung that
    // certifies it — reject instead of silently clamping to the top
    // rung (which would quietly serve at unknown accuracy). Dual-family
    // layers route through the cross-method EngineRouter (tol → winning
    // family + its rung); single-family layers keep the truncation
    // table and their registration family.
    let (routed, tightest) = match &layer.router {
        Some(router) => {
            (router.route_checked(req.tol), router.tightest_calibrated())
        }
        None => {
            let table = layer.table.lock().unwrap();
            (
                table.k_for_checked(req.tol).map(|k| (layer.family(), k)),
                table.tightest_calibrated(),
            )
        }
    };
    let Some((family, k)) = routed else {
        metrics
            .failures
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let _ = reply_tx.send(Reply::Err(Failure {
            id: req.id,
            kind: FailureKind::Invalid,
            error: format!(
                "requested tolerance {:.1e} exceeds the registered \
                 truncation table for layer '{}' (tightest calibrated \
                 tolerance: {}); relax the tolerance or recalibrate \
                 the layer",
                req.tol,
                req.layer,
                tightest
                    .map_or("none".to_string(), |t| format!("{t:.1e}")),
            ),
        }));
        return None;
    };
    // cross-method choice observability: only routed layers move these
    // counters
    if layer.router.is_some() {
        let ord = std::sync::atomic::Ordering::Relaxed;
        match family {
            EngineFamily::Admm => {
                metrics.router_admm_picks.fetch_add(1, ord)
            }
            EngineFamily::AltDiff => {
                metrics.router_altdiff_picks.fetch_add(1, ord)
            }
            EngineFamily::Fw => {
                metrics.router_fw_picks.fetch_add(1, ord)
            }
        };
    }
    Some((family, k, req))
}

/// One shard's router thread: drain the shard's bounded submit queue,
/// route (tol→k), batch, and publish formed batches on the shard's
/// batch queue. Pauses draining while the formed-batch backlog is at
/// the shard's bound (backpressure: arrivals then pile into the bounded
/// submit queue, whose overflow sheds at `submit` time), and counts
/// every deadline flush as a partial flush — a group can only sit in
/// the batcher with fewer than `max_batch` members, so an expired
/// flush is partial by construction.
fn shard_router_loop(
    sidx: usize,
    queues: Arc<Vec<ShardQueue>>,
    bqueues: Arc<Vec<BatchQueue>>,
    layers: BTreeMap<String, Arc<RegisteredLayer>>,
    config: Config,
    metrics: Arc<Metrics>,
    reply_tx: Sender<Reply>,
) {
    let ord = std::sync::atomic::Ordering::Relaxed;
    let queue = &queues[sidx];
    let bq = &bqueues[sidx];
    let shard_m = &metrics.shards[sidx];
    let mut batcher =
        Batcher::with_timeout_us(config.max_batch, config.batch_timeout_us);
    let dispatch = |b: Batch| {
        metrics.batches.fetch_add(1, ord);
        shard_m.observe_batch(b.requests.len());
        bq.push(b);
    };
    // Batch-formation deadline checkpoint: a request whose budget
    // elapsed while it sat in the shard's submit queue is shed here —
    // it must not join a batch and consume a solve it can no longer
    // use (principled by the truncation theorem: late work is dropped,
    // timely work is untouched).
    let shed_expired = |req: &Request| {
        metrics.note_deadline_shed(req.priority);
        shard_m.deadline_shed.fetch_add(1, ord);
        let _ = reply_tx.send(Reply::Err(Failure {
            id: req.id,
            kind: FailureKind::DeadlineExceeded,
            error: format!(
                "deadline budget {}µs elapsed in shard {sidx}'s queue",
                req.deadline_us.unwrap_or(0)
            ),
        }));
    };
    loop {
        // sleep until the next batch deadline or a new arrival
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(50));
        let (reqs, shutdown) =
            if bq.depth_elems() >= config.shard_queue.max(1) {
                // formed-batch backlog at the bound: leave arrivals in
                // the bounded submit queue until the workers catch up
                std::thread::sleep(Duration::from_micros(100));
                (Vec::new(), queue.is_shutdown())
            } else {
                queue.pop_all(timeout)
            };
        for req in reqs {
            metrics.requests.fetch_add(1, ord);
            if req.expired() {
                shed_expired(&req);
                continue;
            }
            if let Some((family, k, req)) =
                route_one(req, &layers, &metrics, &reply_tx)
            {
                if let Some(b) = batcher.push(family, k, req) {
                    dispatch(b);
                }
            }
        }
        for b in batcher.flush_expired(Instant::now()) {
            shard_m.partial_flushes.fetch_add(1, ord);
            dispatch(b);
        }
        shard_m.queue_depth.store(
            (queue.len() + batcher.pending_count()) as u64,
            ord,
        );
        metrics.refresh_queue_depth();
        if shutdown {
            break;
        }
    }
    // Graceful drain. Everything accepted into the submit queue before
    // the shutdown flag is routed (the final pop_all below catches
    // requests left queued when the loop exited from the backpressure
    // pause) and flushed to the batch queue; requests arriving after
    // the flag get an explicit `Failure::Shutdown` reply at submit time
    // — reply channels are never silently dropped.
    let (rest, _) = queue.pop_all(Duration::ZERO);
    for req in rest {
        metrics.requests.fetch_add(1, ord);
        if req.expired() {
            shed_expired(&req);
            continue;
        }
        if let Some((family, k, req)) =
            route_one(req, &layers, &metrics, &reply_tx)
        {
            if let Some(b) = batcher.push(family, k, req) {
                dispatch(b);
            }
        }
    }
    for b in batcher.flush_all() {
        dispatch(b);
    }
    shard_m.queue_depth.store(0, ord);
    metrics.refresh_queue_depth();
    bq.close();
}

/// Execute one batch and ship its replies (counting them as the old
/// worker loop did). Shared by the owned-batch and stolen-batch paths.
///
/// Pre-execution deadline checkpoint: members whose budget elapsed
/// while the batch waited in a batch queue (or in a sibling's steal
/// backlog) are split off and answered `DeadlineExceeded` — an expired
/// request never reaches an engine, and the survivors execute as a
/// smaller batch under the same routed k.
#[allow(clippy::too_many_arguments)]
fn run_batch(
    engine: &mut Option<Engine>,
    mut batch: Batch,
    layers: &BTreeMap<String, Arc<RegisteredLayer>>,
    reply_tx: &Sender<Reply>,
    metrics: &Metrics,
    warm: Option<&Mutex<WarmStartCache>>,
    ring: &TraceRing,
) {
    let layer = match layers.get(&*batch.layer) {
        Some(l) => l.clone(),
        None => return,
    };
    let now = Instant::now();
    if batch.requests.iter().any(|r| r.expired_at(now)) {
        let (live, expired): (Vec<Request>, Vec<Request>) = batch
            .requests
            .drain(..)
            .partition(|r| !r.expired_at(now));
        for req in expired {
            metrics.note_deadline_shed(req.priority);
            let _ = reply_tx.send(Reply::Err(Failure {
                id: req.id,
                kind: FailureKind::DeadlineExceeded,
                error: format!(
                    "deadline budget {}µs elapsed before execution",
                    req.deadline_us.unwrap_or(0)
                ),
            }));
        }
        if live.is_empty() {
            return;
        }
        batch.requests = live;
    }
    // execute_batch emits exactly one reply per request, in request
    // order (every path maps `reqs` positionally) — zip for the
    // per-class served/SLO accounting
    let prios: Vec<Priority> =
        batch.requests.iter().map(|r| r.priority).collect();
    for r in batch.requests.iter_mut() {
        r.stamps.stamp(Stage::ExecStart);
    }
    let replies =
        execute_batch(engine, &layer, &batch, metrics, warm, ring);
    for (i, r) in replies.into_iter().enumerate() {
        match &r {
            Reply::Ok(resp) => {
                metrics
                    .responses
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                metrics.observe_latency(resp.latency);
                metrics.note_served(
                    prios.get(i).copied().unwrap_or_default(),
                    resp.latency,
                );
            }
            Reply::Grad(resp) => {
                metrics
                    .responses
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                metrics.observe_latency(resp.latency);
                metrics.note_served(
                    prios.get(i).copied().unwrap_or_default(),
                    resp.latency,
                );
            }
            Reply::Err(_) => {
                metrics
                    .failures
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            }
        }
        let _ = reply_tx.send(r);
    }
}

/// Pick the deepest sibling batch queue and steal its oldest batch.
/// Returns the victim shard index with the batch so the thief can
/// attribute the steal to the shard it relieved.
fn steal_batch(
    own: usize,
    bqueues: &[BatchQueue],
) -> Option<(usize, Batch)> {
    let mut victim = None;
    let mut deepest = 0usize;
    for (i, q) in bqueues.iter().enumerate() {
        if i == own {
            continue;
        }
        let d = q.depth_batches();
        if d > deepest {
            deepest = d;
            victim = Some(i);
        }
    }
    let v = victim?;
    bqueues[v].try_steal().map(|b| (v, b))
}

/// One worker of shard `sidx`: drain the shard's batch queue; when
/// idle, steal the oldest batch from the deepest sibling (ragged-load
/// relief — a formed batch executes identically on any worker, every
/// engine is shared immutably). Exits once every shard's batch queue is
/// closed AND empty, so workers keep helping the pool drain after
/// their own router finished.
#[allow(clippy::too_many_arguments)]
fn shard_worker_loop(
    sidx: usize,
    bqueues: Arc<Vec<BatchQueue>>,
    layers: BTreeMap<String, Arc<RegisteredLayer>>,
    reply_tx: Sender<Reply>,
    metrics: Arc<Metrics>,
    artifacts: Option<PathBuf>,
    ready: Arc<std::sync::atomic::AtomicUsize>,
    warm: Option<Arc<Mutex<WarmStartCache>>>,
    pin: Option<usize>,
    ring: Arc<TraceRing>,
) {
    // best effort, placement-only: a false return changes nothing
    if let Some(cpu) = pin {
        let _ = crate::util::affinity::pin_current_thread(cpu);
    }
    // PJRT engine is constructed inside the worker thread (not Send).
    let mut engine: Option<Engine> =
        artifacts.as_deref().and_then(|dir| Engine::new(dir).ok());
    // Eagerly compile the variants matching registered layer sizes so the
    // first request doesn't pay XLA compile latency (perf: this cut the
    // serve example's max latency from ~3.6s to the steady-state ms range).
    if let Some(eng) = engine.as_mut() {
        let names: Vec<String> = eng
            .manifest
            .variants
            .iter()
            .filter(|v| {
                layers
                    .values()
                    .any(|l| l.n == v.n && l.m == v.m && l.p == v.p)
            })
            .map(|v| v.name.clone())
            .collect();
        for name in names {
            let _ = eng.compile(&name);
        }
    }
    ready.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
    let own = &bqueues[sidx];
    // single shard: nothing to steal, park long between arrivals (the
    // condvar wakes us on push); sharded: short waits so idle workers
    // notice overloaded siblings quickly
    let idle = if bqueues.len() == 1 {
        Duration::from_millis(50)
    } else {
        Duration::from_micros(200)
    };
    loop {
        if let Some(batch) = own.pop_wait(idle) {
            run_batch(
                &mut engine,
                batch,
                &layers,
                &reply_tx,
                &metrics,
                warm.as_deref(),
                &ring,
            );
            continue;
        }
        if let Some((victim, batch)) = steal_batch(sidx, &bqueues) {
            let ord = std::sync::atomic::Ordering::Relaxed;
            metrics.shards[victim].steals.fetch_add(1, ord);
            metrics.shards[victim]
                .stolen_elems
                .fetch_add(batch.requests.len() as u64, ord);
            run_batch(
                &mut engine,
                batch,
                &layers,
                &reply_tx,
                &metrics,
                warm.as_deref(),
                &ring,
            );
            continue;
        }
        if bqueues.iter().all(|q| q.drained()) {
            break;
        }
        // own queue already drained but a sibling's router is still
        // live: pop_wait returned instantly, so pace the steal polling
        if own.drained() {
            std::thread::sleep(idle);
        }
    }
}

/// Consult the warm cache for every request of a native batch: returns
/// per-request fingerprints, forward warm iterates, and adjoint seeds
/// (hit/miss counts land in the metrics). One lock hold per batch, not
/// per request.
fn warm_lookup(
    cache: &Mutex<WarmStartCache>,
    layer: &str,
    family: EngineFamily,
    k: usize,
    reqs: &[Request],
    metrics: &Metrics,
) -> (Vec<u64>, Vec<Option<WarmStart>>, Vec<Option<EngineSeed>>) {
    let mut c = cache.lock().unwrap();
    let mut fps = Vec::with_capacity(reqs.len());
    let mut warms = Vec::with_capacity(reqs.len());
    let mut seeds = Vec::with_capacity(reqs.len());
    let mut hits = 0u64;
    for r in reqs {
        let fp = fingerprint(r.session, &r.q, &r.b, &r.h);
        let got = c.get(layer, family, k, fp, &r.q, &r.b, &r.h);
        if got.is_some() {
            hits += 1;
        }
        let (w, a) = got.map_or((None, None), |(w, a)| (Some(w), a));
        fps.push(fp);
        warms.push(w);
        seeds.push(a);
    }
    let ord = std::sync::atomic::Ordering::Relaxed;
    metrics.warm_hits.fetch_add(hits, ord);
    metrics.warm_misses.fetch_add(reqs.len() as u64 - hits, ord);
    (fps, warms, seeds)
}

/// Write a finished native batch's converged iterates back into the
/// warm cache (entry e under fingerprint `fps[e]`, recording the θ the
/// solve ran at for later staleness checks).
#[allow(clippy::too_many_arguments)]
fn warm_writeback(
    cache: &Mutex<WarmStartCache>,
    layer: &str,
    family: EngineFamily,
    k: usize,
    reqs: &[Request],
    fps: &[u64],
    sol: &BatchSolution,
    seeds: Option<&[EngineSeed]>,
) {
    let mut c = cache.lock().unwrap();
    for (e, req) in reqs.iter().enumerate() {
        c.put(
            layer,
            family,
            k,
            fps[e],
            req.q.clone(),
            req.b.clone(),
            req.h.clone(),
            sol.warm_start(e),
            seeds.map(|s| s[e].clone()),
        );
    }
}

/// Primal feasibility ‖[Ax−b; (Gx−h)₊]‖ of a served iterate against the
/// *request's* (b, h), evaluated with whichever solver holds the
/// layer's constraint matrices (the residual is engine-independent).
fn layer_feasibility(
    layer: &RegisteredLayer,
    x: &[f64],
    b: &[f64],
    h: &[f64],
) -> f64 {
    match &layer.engine {
        LayerEngine::Dense { solver, .. } => {
            solver.qp.feasibility_with(x, b, h).0
        }
        LayerEngine::Sparse { solver, .. } => {
            solver.qp.feasibility_with(x, b, h).0
        }
        LayerEngine::Admm { solver, .. } => {
            solver.qp.feasibility_with(x, b, h).0
        }
        LayerEngine::Fw { solver, .. } => {
            solver.qp.feasibility_with(x, b, h).0
        }
    }
}

/// A [`TraceCollector`] watching the batch's sampled members, or `None`
/// when no member is sampled — the engines then run observer-free (the
/// unsampled fast path: no allocation, one branch per iteration).
fn trace_collector(reqs: &[Request]) -> Option<TraceCollector> {
    if !reqs.iter().any(|r| r.sampled) {
        return None;
    }
    let mut c = TraceCollector::new(reqs.len());
    for (e, r) in reqs.iter().enumerate() {
        if r.sampled {
            c.watch(e);
        }
    }
    Some(c)
}

/// Package the sampled members of a finished batch into [`TraceEvent`]s
/// and push them into the ring. `collector = None` on paths with no
/// per-iteration state (PJRT): sampled members then trace with an empty
/// iteration series, which still carries stage spans and the routing
/// outcome.
fn push_trace_events(
    ring: &TraceRing,
    batch: &Batch,
    backend: &'static str,
    mut collector: Option<TraceCollector>,
) {
    for (e, req) in batch.requests.iter().enumerate() {
        if !req.sampled {
            continue;
        }
        let iters = collector
            .as_mut()
            .and_then(|c| c.take(e))
            .unwrap_or_default();
        let mut stamps = req.stamps;
        stamps.stamp(Stage::ExecEnd);
        ring.push(TraceEvent {
            id: req.id,
            layer: batch.layer.to_string(),
            backend,
            class: req.priority.label(),
            k: batch.k,
            batch: batch.requests.len(),
            grad: batch.grad,
            stamps,
            iters,
        });
    }
}

/// Execute one batch on the best available backend.
fn execute_batch(
    engine: &mut Option<Engine>,
    layer: &RegisteredLayer,
    batch: &Batch,
    metrics: &Metrics,
    warm: Option<&Mutex<WarmStartCache>>,
    ring: &TraceRing,
) -> Vec<Reply> {
    let t0 = Instant::now();
    let reqs = &batch.requests;
    // Gradient batches take the adjoint path: one batched forward-only
    // launch plus one batched adjoint launch, always native (no compiled
    // adjoint family exists — and none is needed, the backward is d-free).
    if batch.grad {
        return execute_grad_batch(layer, batch, metrics, warm, ring);
    }
    // PJRT path (dense Alt-Diff-routed batches only — no compiled ADMM
    // family exists): pick the smallest compiled batch size >= len, pad.
    if let (
        EngineFamily::AltDiff,
        LayerEngine::Dense {
            hinv_f32,
            a_f32,
            g_f32,
            batches,
            ..
        },
    ) = (batch.family, &layer.engine)
    {
        if let Some(eng) = engine.as_mut() {
            if let Some(&bsz) =
                batches.iter().find(|&&b| b >= reqs.len())
            {
                match execute_pjrt(
                    eng, layer, batch, bsz, hinv_f32, a_f32, g_f32,
                ) {
                    Ok(mut replies) => {
                        metrics.pjrt_execs.fetch_add(
                            1,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                        metrics.padded_slots.fetch_add(
                            (bsz - reqs.len()) as u64,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                        let lat = t0.elapsed().as_secs_f64();
                        for r in replies.iter_mut() {
                            if let Reply::Ok(resp) = r {
                                resp.latency = lat
                                    + resp.latency; // queue time added below
                            }
                        }
                        // compiled path exposes no per-iteration state:
                        // sampled members trace spans + routing only
                        if reqs.iter().any(|r| r.sampled) {
                            push_trace_events(ring, batch, "pjrt", None);
                        }
                        return replies;
                    }
                    Err(e) => {
                        // fall through to native; record the failure mode
                        let _ = e;
                    }
                }
            }
        }
    }
    // Native fallback: ONE batched launch for the whole Batch — the
    // dense or sparse batch engine depending on the layer. tol=0
    // disables per-element truncation so every element runs exactly k
    // iterations (artifact parity, same contract as the compiled path).
    // A configured warm cache seeds each element's iterate from a prior
    // solve — the fixed-k contract is kept (warm ⇒ a *closer* iterate
    // after the same k, and the forward-mode Jacobian stays valid: its
    // slack gates are correct from iteration 1), so warm solve batches
    // buy accuracy rather than iterations; the iteration savings land
    // on the gradient path, which truncates.
    let ord = std::sync::atomic::Ordering::Relaxed;
    metrics.native_execs.fetch_add(1, ord);
    metrics.native_elems.fetch_add(reqs.len() as u64, ord);
    let warm_ctx = warm.map(|cache| {
        warm_lookup(
            cache,
            &batch.layer,
            batch.family,
            batch.k,
            reqs,
            metrics,
        )
    });
    let warms = warm_ctx.as_ref().map(|(_, w, _)| w.as_slice());
    let opts = Options {
        tol: 0.0,
        max_iter: batch.k,
        backward: BackwardMode::Forward(Param::B),
        rho: layer.rho,
        trace: false,
    };
    let qs: Vec<&[f64]> = reqs.iter().map(|r| r.q.as_slice()).collect();
    let bs: Vec<&[f64]> = reqs.iter().map(|r| r.b.as_slice()).collect();
    let hs: Vec<&[f64]> = reqs.iter().map(|r| r.h.as_slice()).collect();
    // Some only when a member was promoted by the 1-in-N sampler —
    // the common case hands the engines no observer at all
    let mut collector = trace_collector(reqs);
    let (sol, backend): (BatchSolution, &'static str) = if batch.family
        == EngineFamily::Admm
    {
        let (_, batched) = layer
            .admm_engines()
            .expect("ADMM-routed batch on a layer with ADMM engines");
        metrics.admm_execs.fetch_add(1, ord);
        metrics.admm_elems.fetch_add(reqs.len() as u64, ord);
        (
            batched.solve_batch_observed(
                Some(&qs),
                Some(&bs),
                Some(&hs),
                warms,
                &opts,
                collector.as_mut().map(|c| c as &mut dyn IterObserver),
            ),
            "native-admm",
        )
    } else if batch.family == EngineFamily::Fw {
        let (_, batched) = layer
            .fw_engines()
            .expect("FW-routed batch on a layer with FW engines");
        metrics.fw_execs.fetch_add(1, ord);
        metrics.fw_elems.fetch_add(reqs.len() as u64, ord);
        (
            batched.solve_batch_observed(
                Some(&qs),
                Some(&bs),
                Some(&hs),
                warms,
                &opts,
                collector.as_mut().map(|c| c as &mut dyn IterObserver),
            ),
            "native-fw",
        )
    } else {
        match &layer.engine {
            LayerEngine::Dense { batched, .. } => (
                batched.solve_batch_observed(
                    Some(&qs),
                    Some(&bs),
                    Some(&hs),
                    warms,
                    &opts,
                    collector
                        .as_mut()
                        .map(|c| c as &mut dyn IterObserver),
                ),
                "native",
            ),
            LayerEngine::Sparse { batched, .. } => {
                metrics.native_sparse_execs.fetch_add(1, ord);
                // fallible: a blocked-CG breakdown must become per-request
                // failure replies, never a worker panic (which would kill
                // the thread and silently drop every batch routed to it)
                match batched.try_solve_batch_observed(
                    Some(&qs),
                    Some(&bs),
                    Some(&hs),
                    warms,
                    &opts,
                    collector
                        .as_mut()
                        .map(|c| c as &mut dyn IterObserver),
                ) {
                    Ok(sol) => (sol, "native-sparse"),
                    Err(e) => {
                        return reqs
                            .iter()
                            .map(|req| {
                                Reply::Err(Failure {
                                    id: req.id,
                                    kind: FailureKind::Exec,
                                    error: format!(
                                        "sparse batched solve failed: {e}"
                                    ),
                                })
                            })
                            .collect();
                    }
                }
            }
            LayerEngine::Admm { .. } => unreachable!(
                "Alt-Diff-routed batch on an ADMM-only layer"
            ),
            LayerEngine::Fw { .. } => unreachable!(
                "Alt-Diff-routed batch on an FW-only layer"
            ),
        }
    };
    let iters_total: u64 = sol.iters.iter().map(|&i| i as u64).sum();
    if batch.family == EngineFamily::Admm {
        metrics.admm_iters.fetch_add(iters_total, ord);
    } else if batch.family == EngineFamily::Fw {
        metrics.fw_iters.fetch_add(iters_total, ord);
    } else {
        metrics.altdiff_iters.fetch_add(iters_total, ord);
    }
    if let (Some(cache), Some((fps, _, _))) = (warm, warm_ctx.as_ref()) {
        warm_writeback(
            cache,
            &batch.layer,
            batch.family,
            batch.k,
            reqs,
            fps,
            &sol,
            None,
        );
    }
    if collector.is_some() {
        push_trace_events(ring, batch, backend, collector);
    }
    let mut jacs = sol.jacobians.unwrap_or_default().into_iter();
    reqs.iter()
        .zip(sol.xs)
        .map(|(req, x)| {
            let prim = layer_feasibility(layer, &x, &req.b, &req.h);
            let mut stamps = req.stamps;
            stamps.stamp(Stage::ExecEnd);
            Reply::Ok(Response {
                id: req.id,
                x,
                jx: jacs.next().map(|j| j.data).unwrap_or_default(),
                prim_residual: prim,
                k_used: batch.k,
                batch_size: reqs.len(),
                latency: req.submitted.elapsed().as_secs_f64(),
                backend,
                stamps,
                stages: None,
            })
        })
        .collect()
}

/// Execute one adjoint (gradient) batch: forward-only batched solve,
/// then ONE batched adjoint launch over the whole batch's dL/dx seeds.
/// Jacobians never exist, so the replies are O(n+m+p) per request.
///
/// With a warm cache configured, this is where warm starts turn into
/// *saved iterations*: a batch containing any warm element runs both
/// launches with per-element truncation at the batch's tightest
/// requested tolerance (k stays the hard cap — the routing contract is
/// "never more than k", and the stop criterion is the calibrated
/// tolerance itself, so accuracy is preserved by Thm 4.3). Cold-only
/// batches keep the exact-k contract unchanged.
fn execute_grad_batch(
    layer: &RegisteredLayer,
    batch: &Batch,
    metrics: &Metrics,
    warm: Option<&Mutex<WarmStartCache>>,
    ring: &TraceRing,
) -> Vec<Reply> {
    let reqs = &batch.requests;
    metrics
        .adjoint_execs
        .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    metrics.adjoint_elems.fetch_add(
        reqs.len() as u64,
        std::sync::atomic::Ordering::Relaxed,
    );
    let warm_ctx = warm.map(|cache| {
        warm_lookup(
            cache,
            &batch.layer,
            batch.family,
            batch.k,
            reqs,
            metrics,
        )
    });
    let warms = warm_ctx.as_ref().map(|(_, w, _)| w.as_slice());
    let any_warm = warms
        .map(|w| w.iter().any(|e| e.is_some()))
        .unwrap_or(false);
    // tol=0: forward and adjoint both run exactly k iterations (the
    // same routing contract as the solve path) — unless warm elements
    // let the batch truncate early at its tightest requested tolerance.
    let tol = if any_warm {
        reqs.iter().map(|r| r.tol).fold(f64::INFINITY, f64::min)
    } else {
        0.0
    };
    let fopts = Options {
        tol,
        max_iter: batch.k,
        backward: BackwardMode::None,
        rho: layer.rho,
        trace: false,
    };
    let bopts =
        Options { backward: BackwardMode::Adjoint, ..fopts.clone() };
    let qs: Vec<&[f64]> = reqs.iter().map(|r| r.q.as_slice()).collect();
    let bs: Vec<&[f64]> = reqs.iter().map(|r| r.b.as_slice()).collect();
    let hs: Vec<&[f64]> = reqs.iter().map(|r| r.h.as_slice()).collect();
    let vs: Vec<&[f64]> = reqs
        .iter()
        .map(|r| {
            r.grad_v
                .as_deref()
                .expect("gradient batch member carries grad_v")
        })
        .collect();
    let fail = |reqs: &[Request], e: &dyn std::fmt::Display| {
        reqs.iter()
            .map(|req| {
                Reply::Err(Failure {
                    id: req.id,
                    kind: FailureKind::Exec,
                    error: format!("sparse adjoint solve failed: {e}"),
                })
            })
            .collect::<Vec<Reply>>()
    };
    // Sampled members trace the forward launch (the adjoint recursion
    // has no per-iteration primal residual to report)
    let mut collector = trace_collector(reqs);
    // Adjoint seeds in the cache are engine-tagged: each family only
    // ever consumes a seed its own backward iteration produced (a
    // cross-family seed is dropped here, never reinterpreted).
    let (forward, vjp, adj_states, backend): (
        BatchSolution,
        BatchVjp,
        Vec<EngineSeed>,
        &'static str,
    ) = if batch.family == EngineFamily::Admm {
        let (_, batched) = layer
            .admm_engines()
            .expect("ADMM-routed batch on a layer with ADMM engines");
        let ord = std::sync::atomic::Ordering::Relaxed;
        metrics.admm_execs.fetch_add(1, ord);
        metrics.admm_elems.fetch_add(reqs.len() as u64, ord);
        let admm_seeds: Option<Vec<Option<AdmmSeed>>> =
            warm_ctx.as_ref().map(|(_, _, s)| {
                s.iter()
                    .map(|o| o.clone().and_then(EngineSeed::into_admm))
                    .collect()
            });
        let forward = batched.solve_batch_observed(
            Some(&qs),
            Some(&bs),
            Some(&hs),
            warms,
            &fopts,
            collector.as_mut().map(|c| c as &mut dyn IterObserver),
        );
        let (vjp, states) = batched.batch_vjp_from(
            &forward.slack_refs(),
            &vs,
            admm_seeds.as_deref(),
            &bopts,
        );
        let states =
            states.into_iter().map(EngineSeed::Admm).collect();
        (forward, vjp, states, "native-admm")
    } else if batch.family == EngineFamily::Fw {
        let (_, batched) = layer
            .fw_engines()
            .expect("FW-routed batch on a layer with FW engines");
        let ord = std::sync::atomic::Ordering::Relaxed;
        metrics.fw_execs.fetch_add(1, ord);
        metrics.fw_elems.fetch_add(reqs.len() as u64, ord);
        let fw_seeds: Option<Vec<Option<FwSeed>>> =
            warm_ctx.as_ref().map(|(_, _, s)| {
                s.iter()
                    .map(|o| o.clone().and_then(EngineSeed::into_fw))
                    .collect()
            });
        let forward = batched.solve_batch_observed(
            Some(&qs),
            Some(&bs),
            Some(&hs),
            warms,
            &fopts,
            collector.as_mut().map(|c| c as &mut dyn IterObserver),
        );
        let (vjp, states) = batched.batch_vjp_from(
            &forward.slack_refs(),
            &vs,
            fw_seeds.as_deref(),
            &bopts,
        );
        let states = states.into_iter().map(EngineSeed::Fw).collect();
        (forward, vjp, states, "native-fw")
    } else {
        let alt_seeds: Option<Vec<Option<AdjointSeed>>> =
            warm_ctx.as_ref().map(|(_, _, s)| {
                s.iter()
                    .map(|o| o.clone().and_then(EngineSeed::into_altdiff))
                    .collect()
            });
        let seeds = alt_seeds.as_deref();
        match &layer.engine {
            LayerEngine::Dense { batched, .. } => {
                let forward = batched.solve_batch_observed(
                    Some(&qs),
                    Some(&bs),
                    Some(&hs),
                    warms,
                    &fopts,
                    collector
                        .as_mut()
                        .map(|c| c as &mut dyn IterObserver),
                );
                let (vjp, states) = batched.batch_vjp_from(
                    &forward.slack_refs(),
                    &vs,
                    seeds,
                    &bopts,
                );
                let states =
                    states.into_iter().map(EngineSeed::AltDiff).collect();
                (forward, vjp, states, "native")
            }
            LayerEngine::Sparse { batched, .. } => {
                let forward = match batched.try_solve_batch_observed(
                    Some(&qs),
                    Some(&bs),
                    Some(&hs),
                    warms,
                    &fopts,
                    collector
                        .as_mut()
                        .map(|c| c as &mut dyn IterObserver),
                ) {
                    Ok(f) => f,
                    Err(e) => return fail(reqs, &e),
                };
                match batched.try_batch_vjp_from(
                    &forward.slack_refs(),
                    &vs,
                    seeds,
                    &bopts,
                ) {
                    Ok((vjp, states)) => {
                        let states = states
                            .into_iter()
                            .map(EngineSeed::AltDiff)
                            .collect();
                        (forward, vjp, states, "native-sparse")
                    }
                    Err(e) => return fail(reqs, &e),
                }
            }
            LayerEngine::Admm { .. } => unreachable!(
                "Alt-Diff-routed batch on an ADMM-only layer"
            ),
            LayerEngine::Fw { .. } => unreachable!(
                "Alt-Diff-routed batch on an FW-only layer"
            ),
        }
    };
    let iters_total: u64 = forward
        .iters
        .iter()
        .chain(vjp.iters.iter())
        .map(|&i| i as u64)
        .sum();
    if batch.family == EngineFamily::Admm {
        metrics
            .admm_iters
            .fetch_add(iters_total, std::sync::atomic::Ordering::Relaxed);
    } else if batch.family == EngineFamily::Fw {
        metrics
            .fw_iters
            .fetch_add(iters_total, std::sync::atomic::Ordering::Relaxed);
    } else {
        metrics
            .altdiff_iters
            .fetch_add(iters_total, std::sync::atomic::Ordering::Relaxed);
    }
    if let (Some(cache), Some((fps, lookups, _))) =
        (warm, warm_ctx.as_ref())
    {
        // saved iterations: warm elements that truncated under the
        // routed k, on both the forward and the adjoint launch
        let mut saved = 0u64;
        for (e, w) in lookups.iter().enumerate() {
            if w.is_some() {
                saved += (batch.k - forward.iters[e].min(batch.k)) as u64;
                saved += (batch.k - vjp.iters[e].min(batch.k)) as u64;
            }
        }
        metrics
            .warm_iters_saved
            .fetch_add(saved, std::sync::atomic::Ordering::Relaxed);
        warm_writeback(
            cache,
            &batch.layer,
            batch.family,
            batch.k,
            reqs,
            fps,
            &forward,
            Some(&adj_states),
        );
    }
    if collector.is_some() {
        push_trace_events(ring, batch, backend, collector);
    }
    let mut gq = vjp.grads_q.into_iter();
    let mut gb = vjp.grads_b.into_iter();
    let mut gh = vjp.grads_h.into_iter();
    reqs.iter()
        .zip(forward.xs)
        .map(|(req, x)| {
            let prim = layer_feasibility(layer, &x, &req.b, &req.h);
            let mut stamps = req.stamps;
            stamps.stamp(Stage::ExecEnd);
            Reply::Grad(GradientResponse {
                id: req.id,
                x,
                grad_q: gq.next().expect("vjp arity"),
                grad_b: gb.next().expect("vjp arity"),
                grad_h: gh.next().expect("vjp arity"),
                prim_residual: prim,
                k_used: batch.k,
                batch_size: reqs.len(),
                latency: req.submitted.elapsed().as_secs_f64(),
                backend,
                stamps,
                stages: None,
            })
        })
        .collect()
}

fn execute_pjrt(
    eng: &mut Engine,
    layer: &RegisteredLayer,
    batch: &Batch,
    bsz: usize,
    hinv_f32: &[f32],
    a_f32: &[f32],
    g_f32: &[f32],
) -> std::result::Result<Vec<Reply>, AltDiffError> {
    let reqs = &batch.requests;
    let (n, m, p) = (layer.n, layer.m, layer.p);
    let name = format!(
        "qp_n{}_m{}_p{}_k{}_b{}",
        n, m, p, batch.k, bsz
    );
    // pad by repeating the last request's θ
    let mut q = Vec::with_capacity(bsz * n);
    let mut b = Vec::with_capacity(bsz * p);
    let mut h = Vec::with_capacity(bsz * m);
    for i in 0..bsz {
        let r = &reqs[i.min(reqs.len() - 1)];
        q.extend(r.q.iter().map(|&v| v as f32));
        b.extend(r.b.iter().map(|&v| v as f32));
        h.extend(r.h.iter().map(|&v| v as f32));
    }
    let out = eng.execute(
        &name,
        hinv_f32,
        a_f32,
        g_f32,
        &q,
        &b,
        &h,
    )?;
    let mut replies = Vec::with_capacity(reqs.len());
    for (i, req) in reqs.iter().enumerate() {
        let x: Vec<f64> =
            out.x[i * n..(i + 1) * n].iter().map(|&v| v as f64).collect();
        let jx: Vec<f64> = out.jx[i * n * p..(i + 1) * n * p]
            .iter()
            .map(|&v| v as f64)
            .collect();
        let prim = out.prim[i] as f64;
        // online truncation correction (Thm 4.3 in production): if the
        // executable reports a residual above the requested tolerance,
        // future requests at this tolerance get the next rung.
        if out.dual[i] as f64 > req.tol * 10.0 {
            layer.table.lock().unwrap().bump(req.tol);
        }
        let mut stamps = req.stamps;
        stamps.stamp(Stage::ExecEnd);
        replies.push(Reply::Ok(Response {
            id: req.id,
            x,
            jx,
            prim_residual: prim,
            k_used: batch.k,
            batch_size: reqs.len(),
            latency: req.submitted.elapsed().as_secs_f64(),
            backend: "pjrt",
            stamps,
            stages: None,
        }));
    }
    Ok(replies)
}

impl Coordinator {
    /// Start building a coordinator (register layers, then `start`).
    pub fn builder(config: Config) -> CoordinatorBuilder {
        CoordinatorBuilder::new(config)
    }

    /// Block until every worker finished warmup (compiled its artifact
    /// set). Serving benchmarks call this so startup cost is not billed
    /// to request latency.
    pub fn wait_ready(&self, timeout: Duration) -> bool {
        let t0 = Instant::now();
        while self.ready.load(std::sync::atomic::Ordering::SeqCst)
            < self.n_workers
        {
            if t0.elapsed() > timeout {
                return false;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        true
    }

    /// Shards in the pool (≥ 1).
    pub fn shard_count(&self) -> usize {
        self.queues.len()
    }

    /// Current depth of every shard's submit queue — the health
    /// endpoint reads this to report backlog saturation without
    /// touching the routers' locks for longer than a `len()`.
    pub fn shard_queue_depths(&self) -> Vec<usize> {
        self.queues.iter().map(|q| q.len()).collect()
    }

    /// The per-shard backlog bound the queues were built with
    /// ([`Config::shard_queue`], clamped to ≥ 1).
    pub fn shard_queue_cap(&self) -> usize {
        self.queues.first().map(|q| q.cap).unwrap_or(1)
    }

    /// Whether stage-stamp tracing is on ([`Config::stamps`]). The net
    /// front end consults this to build enabled stamp records at
    /// frame-accept time.
    pub fn stamps_enabled(&self) -> bool {
        self.stamps_on
    }

    /// A stamp record matching the server's tracing configuration:
    /// enabled when [`Config::stamps`] is set, inert otherwise.
    pub fn new_stamps(&self) -> StageStamps {
        if self.stamps_on {
            StageStamps::enabled()
        } else {
            StageStamps::off()
        }
    }

    /// The trace ring (finished sampled solver traces). The net front
    /// end drains it for `GET /trace`; always present, empty unless
    /// [`Config::trace_every`] > 0.
    pub fn trace_ring(&self) -> Arc<TraceRing> {
        self.ring.clone()
    }

    /// Submit an already-built [`Request`] (the network front end's
    /// path: the request was constructed at frame-decode time and its
    /// `submitted` timestamp is preserved, so served latency includes
    /// time spent queued in the event loop's tick). The coordinator
    /// assigns and returns its own correlation id, overwriting
    /// `req.id`.
    ///
    /// Routing: a request with a session key lands on
    /// `shard_for(layer, session)` — deterministic, so its warm-start
    /// state stays on one shard; session-less requests round-robin for
    /// load spread. A full shard sheds here with
    /// `FailureKind::Overloaded` (retryable), and a draining one
    /// answers `FailureKind::Shutdown`; both arrive as ordinary replies
    /// under the returned id.
    pub fn submit_request(&mut self, mut req: Request) -> u64 {
        self.next_id += 1;
        req.id = self.next_id;
        let id = self.next_id;
        // tracing plane admission: in-process submissions get a fresh
        // enabled record here (net-front-end requests already carry one
        // with accepted/decoded taken); the sampler promotes 1-in-N
        // requests to full solver traces
        if self.stamps_on && !req.stamps.is_on() {
            req.stamps = StageStamps::enabled();
        }
        req.stamps.stamp(Stage::Enqueued);
        if !req.sampled {
            req.sampled = self.sampler.sample();
        }
        let shard = match req.session {
            Some(s) => shard_for(&req.layer, s, self.queues.len()),
            None => {
                self.rr = self.rr.wrapping_add(1);
                (self.rr % self.queues.len() as u64) as usize
            }
        };
        let prio = req.priority;
        match self.queues[shard].push(req) {
            PushOutcome::Queued => {}
            PushOutcome::Full => {
                let ord = std::sync::atomic::Ordering::Relaxed;
                self.metrics.note_shed(prio);
                self.metrics.shards[shard].shed.fetch_add(1, ord);
                if let Some(tx) = &self.reply_tx {
                    let _ = tx.send(Reply::Err(Failure {
                        id,
                        kind: FailureKind::Overloaded,
                        error: format!(
                            "shard {shard} is at its backlog bound \
                             for class {}",
                            prio.label()
                        ),
                    }));
                }
            }
            PushOutcome::Draining => {
                let ord = std::sync::atomic::Ordering::Relaxed;
                self.metrics.drained.fetch_add(1, ord);
                self.metrics.failures.fetch_add(1, ord);
                if let Some(tx) = &self.reply_tx {
                    let _ = tx.send(Reply::Err(Failure {
                        id,
                        kind: FailureKind::Shutdown,
                        error: "coordinator is shutting down".to_string(),
                    }));
                }
            }
        }
        id
    }

    /// Submit a request; returns its id. Replies arrive on [`Self::recv`].
    pub fn submit(
        &mut self,
        layer: &str,
        q: Vec<f64>,
        b: Vec<f64>,
        h: Vec<f64>,
        tol: f64,
    ) -> u64 {
        self.submit_request(Request {
            id: 0,
            layer: layer.to_string(),
            q,
            b,
            h,
            tol,
            grad_v: None,
            session: None,
            priority: Priority::default(),
            deadline_us: None,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        })
    }

    /// [`Self::submit`] under a warm-start session key: repeated
    /// submissions with the same key share a slot in the configured
    /// [`crate::warm::WarmStartCache`] (no-op routing-wise when the
    /// cache is disabled — see [`Config::warm_capacity`]).
    pub fn submit_session(
        &mut self,
        layer: &str,
        q: Vec<f64>,
        b: Vec<f64>,
        h: Vec<f64>,
        tol: f64,
        session: u64,
    ) -> u64 {
        self.submit_request(Request {
            id: 0,
            layer: layer.to_string(),
            q,
            b,
            h,
            tol,
            grad_v: None,
            session: Some(session),
            priority: Priority::default(),
            deadline_us: None,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        })
    }

    /// Submit an adjoint (gradient) request: solve the layer for θ and
    /// reply with vᵀ∂x*/∂θ for every parameter ([`Reply::Grad`]) — the
    /// training path. Jacobians never cross the channel.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_grad(
        &mut self,
        layer: &str,
        q: Vec<f64>,
        b: Vec<f64>,
        h: Vec<f64>,
        v: Vec<f64>,
        tol: f64,
    ) -> u64 {
        self.submit_request(Request {
            id: 0,
            layer: layer.to_string(),
            q,
            b,
            h,
            tol,
            grad_v: Some(v),
            session: None,
            priority: Priority::default(),
            deadline_us: None,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        })
    }

    /// [`Self::submit_grad`] under a warm-start session key (see
    /// [`Self::submit_session`]): warm gradient batches may stop under
    /// the routed k at the batch's tightest requested tolerance, which
    /// is where [`Metrics::warm_iters_saved`] accrues.
    #[allow(clippy::too_many_arguments)]
    pub fn submit_grad_session(
        &mut self,
        layer: &str,
        q: Vec<f64>,
        b: Vec<f64>,
        h: Vec<f64>,
        v: Vec<f64>,
        tol: f64,
        session: u64,
    ) -> u64 {
        self.submit_request(Request {
            id: 0,
            layer: layer.to_string(),
            q,
            b,
            h,
            tol,
            grad_v: Some(v),
            session: Some(session),
            priority: Priority::default(),
            deadline_us: None,
            submitted: Instant::now(),
            stamps: StageStamps::off(),
            sampled: false,
            echo_stages: false,
        })
    }

    /// Blocking receive of the next reply.
    pub fn recv(&self) -> Option<Reply> {
        self.reply_rx.recv().ok()
    }

    /// Nonblocking receive: `None` when no reply is currently queued.
    /// The network front end's event loop polls this between socket
    /// readiness sweeps instead of parking on the channel.
    pub fn try_recv(&self) -> Option<Reply> {
        self.reply_rx.try_recv().ok()
    }

    /// Registered layers as `(name, n, m, p)` — the wire protocol's
    /// layer-discovery op serves this so remote load generators can
    /// synthesize well-formed θ without out-of-band configuration.
    pub fn layer_dims(&self) -> &[(String, usize, usize, usize)] {
        &self.layer_dims
    }

    /// Blocking receive with a timeout; `None` on expiry/disconnect.
    pub fn recv_timeout(&self, d: Duration) -> Option<Reply> {
        self.reply_rx.recv_timeout(d).ok()
    }

    /// Submit many, wait for all (convenience for examples/benches).
    pub fn run_all(
        &mut self,
        layer: &str,
        thetas: Vec<(Vec<f64>, Vec<f64>, Vec<f64>)>,
        tol: f64,
    ) -> Vec<Reply> {
        let count = thetas.len();
        for (q, b, h) in thetas {
            self.submit(layer, q, b, h, tol);
        }
        let mut out = Vec::with_capacity(count);
        while out.len() < count {
            match self.recv_timeout(Duration::from_secs(60)) {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out.sort_by_key(|r| r.id());
        out
    }

    /// Graceful shutdown (also runs on Drop): flag every shard queue,
    /// join the routers (each drains its queue, flushes its batcher,
    /// and closes its batch queue), then join the workers (which keep
    /// executing — and stealing — until every batch queue is drained).
    /// Already-accepted requests are served; late arrivals get
    /// `Failure::Shutdown` replies. Finally the coordinator's reply
    /// sender is dropped so `recv` disconnects once the buffered
    /// replies are consumed.
    pub fn shutdown(&mut self) {
        for q in self.queues.iter() {
            q.begin_shutdown();
        }
        for r in self.routers.drain(..) {
            let _ = r.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.reply_tx = None;
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}
