//! L3: the optimization-layer serving coordinator.
//!
//! The paper's truncation theory (§4.3) becomes a serving policy here:
//! requests carry a tolerance; the router maps it to a compiled iteration
//! count via a calibrated [`truncation::TruncationTable`]; the
//! [`batcher::Batcher`] groups compatible requests; workers execute the
//! AOT PJRT artifacts (or the native engine as fallback/oracle).
pub mod batcher;
pub mod messages;
pub mod metrics;
pub mod server;
pub mod truncation;

pub use batcher::{Batch, Batcher};
pub use messages::{
    Failure, FailureKind, GradientResponse, Reply, Request, Response,
};
pub use metrics::Metrics;
pub use server::{
    Config, Coordinator, CoordinatorBuilder, LayerEngine, RegisteredLayer,
};
pub use truncation::TruncationTable;
