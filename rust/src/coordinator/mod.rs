//! L3: the optimization-layer serving coordinator.
//!
//! The paper's truncation theory (§4.3) becomes a serving policy here:
//! requests carry a tolerance; the router maps it to a compiled iteration
//! count via a calibrated [`truncation::TruncationTable`]; the
//! [`batcher::Batcher`] groups compatible requests; workers execute the
//! AOT PJRT artifacts (or the native engine as fallback/oracle).
//!
//! The scheduler is a **shard pool** ([`server::shard_for`]): each shard
//! owns a bounded submit queue, a router thread with a private batcher
//! (deadline-aware — `batch_timeout_us` flushes partial batches), and a
//! slice of the worker pool; idle workers steal formed batches from the
//! deepest sibling shard. Per-shard counters (queue depth, steals,
//! partial flushes, occupancy histogram) live in
//! [`metrics::ShardMetrics`] and render in the Prometheus text.
//!
//! Layers registered via
//! [`server::CoordinatorBuilder::register_routed`] carry BOTH engine
//! families (Alt-Diff and ADMM) plus a [`truncation::EngineRouter`]
//! calibrated from fixed-k probes of each — the shard routers then route
//! every request to the per-tolerance winning family, observable in the
//! [`Metrics`] router counters.
pub mod batcher;
pub mod messages;
pub mod metrics;
pub mod server;
pub mod truncation;

pub use batcher::{Batch, Batcher};
pub use messages::{
    Failure, FailureKind, GradientResponse, Priority, Reply, Request,
    Response,
};
pub use metrics::{Metrics, ShardMetrics};
pub use server::{
    class_budget, shard_for, AdmmEngines, Config, Coordinator,
    CoordinatorBuilder, LayerEngine, RegisteredLayer,
};
pub use truncation::{EngineRouter, TruncationTable};
