//! Lock-free serving metrics (atomics only on the hot path).

use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram buckets (microseconds, upper bounds).
pub const LAT_BUCKETS_US: [u64; 8] =
    [50, 100, 250, 500, 1_000, 5_000, 25_000, u64::MAX];

/// Counters and latency histogram shared by dispatcher and workers.
#[derive(Default)]
pub struct Metrics {
    /// requests accepted by the dispatcher
    pub requests: AtomicU64,
    /// successful replies sent
    pub responses: AtomicU64,
    /// failure replies sent
    pub failures: AtomicU64,
    /// batches dispatched to workers
    pub batches: AtomicU64,
    /// compiled-artifact executions
    pub pjrt_execs: AtomicU64,
    /// native batched launches (one per `Batch`, not per request)
    pub native_execs: AtomicU64,
    /// the subset of native launches executed by the sparse batch engine
    pub native_sparse_execs: AtomicU64,
    /// requests served by native launches (occupancy numerator)
    pub native_elems: AtomicU64,
    /// adjoint (gradient) batched launches — one per gradient `Batch`;
    /// these ship vᵀ∂x/∂θ instead of Jacobians over the channel
    pub adjoint_execs: AtomicU64,
    /// gradient requests served by adjoint launches
    pub adjoint_elems: AtomicU64,
    /// slots wasted by padding partial batches to the artifact batch size
    pub padded_slots: AtomicU64,
    /// truncation-table online corrections
    pub bumps: AtomicU64,
    /// summed end-to-end latency (µs) over all responses
    pub total_latency_us: AtomicU64,
    lat_hist: [AtomicU64; 8],
}

impl Metrics {
    /// All-zero metrics.
    pub fn new() -> Self {
        Metrics::default()
    }

    /// Record one response's end-to-end latency (seconds).
    pub fn observe_latency(&self, secs: f64) {
        let us = (secs * 1e6) as u64;
        self.total_latency_us.fetch_add(us, Ordering::Relaxed);
        for (i, &ub) in LAT_BUCKETS_US.iter().enumerate() {
            if us <= ub {
                self.lat_hist[i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }

    /// Mean end-to-end latency in microseconds (0 with no responses).
    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.total_latency_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate latency quantile from the histogram.
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let total: u64 =
            self.lat_hist.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil() as u64;
        let mut acc = 0;
        for (i, b) in self.lat_hist.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return LAT_BUCKETS_US[i];
            }
        }
        u64::MAX
    }

    /// Mean requests per native batched launch (0 when nothing ran
    /// natively) — the batcher's win on the fallback path.
    pub fn native_batch_occupancy(&self) -> f64 {
        let execs = self.native_execs.load(Ordering::Relaxed);
        if execs == 0 {
            return 0.0;
        }
        self.native_elems.load(Ordering::Relaxed) as f64 / execs as f64
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "req={} resp={} fail={} batches={} pjrt={} native={} \
             sparse={} adjoint={} native_occ={:.1} pad={} bumps={} \
             mean_lat={:.0}us p90<={}us",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.failures.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.pjrt_execs.load(Ordering::Relaxed),
            self.native_execs.load(Ordering::Relaxed),
            self.native_sparse_execs.load(Ordering::Relaxed),
            self.adjoint_execs.load(Ordering::Relaxed),
            self.native_batch_occupancy(),
            self.padded_slots.load(Ordering::Relaxed),
            self.bumps.load(Ordering::Relaxed),
            self.mean_latency_us(),
            match self.latency_quantile_us(0.9) {
                u64::MAX => 999_999_999, // top (unbounded) bucket
                v => v,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn latency_accounting() {
        let m = Metrics::new();
        m.responses.store(2, Ordering::Relaxed);
        m.observe_latency(100e-6);
        m.observe_latency(300e-6);
        assert!((m.mean_latency_us() - 200.0).abs() < 1.0);
        assert!(m.latency_quantile_us(0.5) <= 500);
        assert!(m.latency_quantile_us(1.0) >= 250);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.latency_quantile_us(0.9), 0);
        assert!(m.summary().contains("req=0"));
        assert_eq!(m.native_batch_occupancy(), 0.0);
    }

    #[test]
    fn native_occupancy_is_elems_per_launch() {
        let m = Metrics::new();
        m.native_execs.store(4, Ordering::Relaxed);
        m.native_elems.store(10, Ordering::Relaxed);
        assert!((m.native_batch_occupancy() - 2.5).abs() < 1e-12);
        assert!(m.summary().contains("native_occ=2.5"));
    }
}
