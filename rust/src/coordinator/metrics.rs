//! Lock-free serving metrics (atomics only on the hot path).

use super::messages::Priority;
use crate::obs::{StageSpans, N_SPANS, SPAN_LABELS};
use std::sync::atomic::{AtomicU64, Ordering};

/// Latency histogram buckets (microseconds, upper bounds).
pub const LAT_BUCKETS_US: [u64; 8] =
    [50, 100, 250, 500, 1_000, 5_000, 25_000, u64::MAX];

/// Per-class latency SLO thresholds in microseconds, indexed by
/// [`Priority::idx`] (High, Normal, Low). A served reply whose
/// end-to-end latency is within its class budget counts `slo_ok`,
/// otherwise `slo_miss` — the pair gives an instant per-class SLO
/// attainment ratio without histogram math.
pub const SLO_BUDGET_US: [u64; 3] = [5_000, 25_000, 100_000];

/// Batch-occupancy histogram buckets (requests per formed batch, upper
/// bounds). The last bucket is +Inf.
pub const OCC_BUCKETS: [u64; 6] = [1, 2, 4, 8, 16, u64::MAX];

/// Per-shard scheduler counters. One slot per coordinator shard lives in
/// [`Metrics::shards`]; the shard's router thread owns the gauge, the
/// router and (for steals) sibling workers bump the counters.
#[derive(Default)]
pub struct ShardMetrics {
    /// gauge: requests waiting on this shard (bounded submit queue plus
    /// the shard batcher's pending map; refreshed by the shard router)
    pub queue_depth: AtomicU64,
    /// batches this shard's batcher formed (full or timeout-flushed)
    pub batches: AtomicU64,
    /// requests carried by those batches (occupancy numerator; summed
    /// over shards this equals `native_elems + adjoint_elems` when no
    /// PJRT artifacts are loaded)
    pub elems: AtomicU64,
    /// batches flushed by `batch_timeout_us` before reaching `max_batch`
    pub partial_flushes: AtomicU64,
    /// formed batches stolen *from* this shard by an idle sibling worker
    pub steals: AtomicU64,
    /// requests carried by stolen batches
    pub stolen_elems: AtomicU64,
    /// requests this shard's bounded submit queue shed (Overloaded) —
    /// the per-shard slice of the global `shed` counter
    pub shed: AtomicU64,
    /// requests shed `DeadlineExceeded` at this shard's batch-formation
    /// checkpoint (expired while queued; pre-execution sheds count only
    /// in the global `deadline_shed` — a stolen batch may execute on a
    /// sibling's worker, so attribution stops at the router)
    pub deadline_shed: AtomicU64,
    /// occupancy histogram over formed batches (buckets [`OCC_BUCKETS`])
    pub occ_hist: [AtomicU64; 6],
}

impl ShardMetrics {
    /// Record one formed batch of `elems` requests.
    pub fn observe_batch(&self, elems: usize) {
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.elems.fetch_add(elems as u64, Ordering::Relaxed);
        for (i, &ub) in OCC_BUCKETS.iter().enumerate() {
            if elems as u64 <= ub {
                self.occ_hist[i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }
}

/// Counters and latency histogram shared by shard routers and workers.
#[derive(Default)]
pub struct Metrics {
    /// requests accepted by the shard routers
    pub requests: AtomicU64,
    /// successful replies sent
    pub responses: AtomicU64,
    /// failure replies sent
    pub failures: AtomicU64,
    /// batches dispatched to workers
    pub batches: AtomicU64,
    /// compiled-artifact executions
    pub pjrt_execs: AtomicU64,
    /// native batched launches (one per `Batch`, not per request)
    pub native_execs: AtomicU64,
    /// the subset of native launches executed by the sparse batch engine
    pub native_sparse_execs: AtomicU64,
    /// native launches executed by the ADMM engine family (forward +
    /// adjoint; disjoint from the Alt-Diff native/sparse counters)
    pub admm_execs: AtomicU64,
    /// requests served by ADMM launches
    pub admm_elems: AtomicU64,
    /// routed batches the cross-method router sent to the ADMM family
    pub router_admm_picks: AtomicU64,
    /// routed batches the cross-method router kept on Alt-Diff
    pub router_altdiff_picks: AtomicU64,
    /// solver iterations run by ADMM launches (summed over elements)
    pub admm_iters: AtomicU64,
    /// native launches executed by the Frank–Wolfe engine family
    /// (forward + adjoint; disjoint from the other native counters)
    pub fw_execs: AtomicU64,
    /// requests served by FW launches
    pub fw_elems: AtomicU64,
    /// routed batches the cross-method router sent to the FW family
    pub router_fw_picks: AtomicU64,
    /// solver iterations run by FW launches (summed over elements)
    pub fw_iters: AtomicU64,
    /// solver iterations run by native Alt-Diff launches (summed over
    /// elements; PJRT executions are fixed-k and not counted here)
    pub altdiff_iters: AtomicU64,
    /// requests served by native launches (occupancy numerator)
    pub native_elems: AtomicU64,
    /// adjoint (gradient) batched launches — one per gradient `Batch`;
    /// these ship vᵀ∂x/∂θ instead of Jacobians over the channel
    pub adjoint_execs: AtomicU64,
    /// gradient requests served by adjoint launches
    pub adjoint_elems: AtomicU64,
    /// slots wasted by padding partial batches to the artifact batch size
    pub padded_slots: AtomicU64,
    /// warm-start cache hits: requests that resumed from a cached
    /// iterate (only moves when the coordinator's warm cache is enabled)
    pub warm_hits: AtomicU64,
    /// warm-start cache lookups that found nothing usable (absent,
    /// stale, or mismatched dimensions)
    pub warm_misses: AtomicU64,
    /// iterations below the routed k that warm-enabled early stopping
    /// avoided (summed over warm batch elements, forward + adjoint)
    pub warm_iters_saved: AtomicU64,
    /// truncation-table online corrections
    pub bumps: AtomicU64,
    /// requests shed by admission control (the network front end replies
    /// `Failure::Overloaded` instead of queueing past its budget, and a
    /// full bounded shard queue sheds the same way)
    pub shed: AtomicU64,
    /// requests answered `Failure::Shutdown` because a graceful drain was
    /// already underway when they arrived or were still queued
    pub drained: AtomicU64,
    /// requests shed `DeadlineExceeded` at ANY checkpoint (net
    /// admission, batch formation, pre-execution). Every such shed
    /// sends exactly one failure reply, so this equals the
    /// DeadlineExceeded replies clients observe — the chaos suite
    /// reconciles the two sides against this counter.
    pub deadline_shed: AtomicU64,
    /// per-class slice of `shed` (Overloaded), indexed by
    /// [`Priority::idx`] — under pressure Low should lead Normal
    /// should lead High
    pub shed_by_class: [AtomicU64; 3],
    /// per-class slice of `deadline_shed`, indexed by [`Priority::idx`]
    pub deadline_by_class: [AtomicU64; 3],
    /// successfully served replies per class, indexed by
    /// [`Priority::idx`] (sums to the class-attributable subset of
    /// `responses`; coordinator-internal failure replies have no class
    /// row)
    pub served_by_class: [AtomicU64; 3],
    /// served replies within their class latency SLO
    /// ([`SLO_BUDGET_US`]), indexed by [`Priority::idx`]
    pub slo_ok_by_class: [AtomicU64; 3],
    /// served replies over their class latency SLO, indexed by
    /// [`Priority::idx`]
    pub slo_miss_by_class: [AtomicU64; 3],
    /// gauge: requests currently waiting across every shard (sum of the
    /// per-shard gauges; shard routers refresh their own slice)
    pub queue_depth: AtomicU64,
    /// gauge: requests admitted by the network front end and not yet
    /// answered (the serving in-flight budget's numerator)
    pub net_inflight: AtomicU64,
    /// summed end-to-end latency (µs) over all responses
    pub total_latency_us: AtomicU64,
    lat_hist: [AtomicU64; 8],
    /// per-(class × stage) latency histograms over the tracing plane's
    /// stage spans ([`crate::obs::StageStamps`]), indexed
    /// `[Priority::idx()][span][bucket]` with [`LAT_BUCKETS_US`]
    /// buckets. Only populated when the server runs with stage stamps
    /// enabled — the stamp record is inert otherwise and the net front
    /// end never calls [`Metrics::note_stages`].
    pub stage_hist: [[[AtomicU64; 8]; N_SPANS]; 3],
    /// per-(class × stage) summed span µs (the histogram `_sum` rows)
    pub stage_sum_us: [[AtomicU64; N_SPANS]; 3],
    /// per-class count of stage-stamped replies (the `_count` rows,
    /// shared by all six spans of a class)
    pub stage_count: [AtomicU64; 3],
    /// per-shard scheduler counters (length = shard count, ≥ 1 when
    /// built by a coordinator; empty under plain `Default`)
    pub shards: Vec<ShardMetrics>,
}

impl Metrics {
    /// All-zero metrics with a single shard slot.
    pub fn new() -> Self {
        Metrics::for_shards(1)
    }

    /// All-zero metrics with `n` shard slots (`n` clamped to ≥ 1).
    pub fn for_shards(n: usize) -> Self {
        Metrics {
            shards: (0..n.max(1)).map(|_| ShardMetrics::default()).collect(),
            ..Metrics::default()
        }
    }

    /// Refresh the global queue-depth gauge as the sum of the per-shard
    /// gauges. Each shard router calls this after updating its own slot.
    pub fn refresh_queue_depth(&self) {
        let sum: u64 = self
            .shards
            .iter()
            .map(|s| s.queue_depth.load(Ordering::Relaxed))
            .sum();
        self.queue_depth.store(sum, Ordering::Relaxed);
    }

    /// Record one response's end-to-end latency (seconds).
    pub fn observe_latency(&self, secs: f64) {
        let us = (secs * 1e6) as u64;
        self.total_latency_us.fetch_add(us, Ordering::Relaxed);
        for (i, &ub) in LAT_BUCKETS_US.iter().enumerate() {
            if us <= ub {
                self.lat_hist[i].fetch_add(1, Ordering::Relaxed);
                break;
            }
        }
    }

    /// Record one Overloaded shed of a `p`-class request: the global
    /// shed + failure counters plus the class row, in one place so the
    /// global and per-class totals reconcile by construction.
    pub fn note_shed(&self, p: Priority) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        self.failures.fetch_add(1, Ordering::Relaxed);
        self.shed_by_class[p.idx()].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one DeadlineExceeded shed of a `p`-class request
    /// (whichever checkpoint caught it). Counts the failure reply too —
    /// callers send exactly one reply per call, which is what keeps the
    /// server counter equal to the client-observed DeadlineExceeded
    /// tally.
    pub fn note_deadline_shed(&self, p: Priority) {
        self.deadline_shed.fetch_add(1, Ordering::Relaxed);
        self.failures.fetch_add(1, Ordering::Relaxed);
        self.deadline_by_class[p.idx()].fetch_add(1, Ordering::Relaxed);
    }

    /// Record one served `p`-class reply and judge it against the class
    /// latency SLO ([`SLO_BUDGET_US`]).
    pub fn note_served(&self, p: Priority, latency_secs: f64) {
        let i = p.idx();
        self.served_by_class[i].fetch_add(1, Ordering::Relaxed);
        let us = (latency_secs * 1e6) as u64;
        if us <= SLO_BUDGET_US[i] {
            self.slo_ok_by_class[i].fetch_add(1, Ordering::Relaxed);
        } else {
            self.slo_miss_by_class[i].fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one stamped reply's stage breakdown against the
    /// per-(class × stage) histograms. Called by the net front end at
    /// reply-write time (the only point where every span is known).
    pub fn note_stages(&self, p: Priority, spans: &StageSpans) {
        let ci = p.idx();
        self.stage_count[ci].fetch_add(1, Ordering::Relaxed);
        for (si, &us) in spans.iter().enumerate() {
            self.stage_sum_us[ci][si]
                .fetch_add(us as u64, Ordering::Relaxed);
            for (bi, &ub) in LAT_BUCKETS_US.iter().enumerate() {
                if us as u64 <= ub {
                    self.stage_hist[ci][si][bi]
                        .fetch_add(1, Ordering::Relaxed);
                    break;
                }
            }
        }
    }

    /// Mean end-to-end latency in microseconds (0 with no responses).
    pub fn mean_latency_us(&self) -> f64 {
        let n = self.responses.load(Ordering::Relaxed);
        if n == 0 {
            return 0.0;
        }
        self.total_latency_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate latency quantile from the histogram, linearly
    /// interpolated within the winning bucket (uniform-within-bucket
    /// assumption) rather than snapped to the bucket's upper bound —
    /// so p50/p99 move continuously instead of quantizing to the 8
    /// bucket edges. A quantile landing in the unbounded overflow
    /// bucket returns `u64::MAX` (there is no finite upper bound to
    /// interpolate toward; `summary()` prints it as 999999999us).
    pub fn latency_quantile_us(&self, q: f64) -> u64 {
        let counts: Vec<u64> = self
            .lat_hist
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if acc + c >= target && c > 0 {
                let hi = LAT_BUCKETS_US[i];
                if hi == u64::MAX {
                    return u64::MAX;
                }
                let lo = if i == 0 { 0 } else { LAT_BUCKETS_US[i - 1] };
                // rank within this bucket is 1..=c → fraction (0, 1]
                let frac = (target - acc) as f64 / c as f64;
                return lo + ((hi - lo) as f64 * frac).round() as u64;
            }
            acc += c;
        }
        u64::MAX
    }

    /// Mean requests per native batched launch (0 when nothing ran
    /// natively) — the batcher's win on the fallback path.
    pub fn native_batch_occupancy(&self) -> f64 {
        let execs = self.native_execs.load(Ordering::Relaxed);
        if execs == 0 {
            return 0.0;
        }
        self.native_elems.load(Ordering::Relaxed) as f64 / execs as f64
    }

    /// Prometheus-style text rendering of every counter, the two queue
    /// gauges, and the latency histogram (cumulative `le` buckets per the
    /// exposition format). Served over the wire by the stats op of
    /// [`crate::net`] and printed by `serve` on exit.
    pub fn render_text(&self) -> String {
        let c = |out: &mut String, name: &str, help: &str, v: u64| {
            out.push_str(&format!(
                "# HELP altdiff_{name} {help}\n\
                 # TYPE altdiff_{name} counter\n\
                 altdiff_{name} {v}\n"
            ));
        };
        let g = |out: &mut String, name: &str, help: &str, v: u64| {
            out.push_str(&format!(
                "# HELP altdiff_{name} {help}\n\
                 # TYPE altdiff_{name} gauge\n\
                 altdiff_{name} {v}\n"
            ));
        };
        let ld = Ordering::Relaxed;
        let mut out = String::new();
        c(
            &mut out,
            "requests_total",
            "requests accepted by the shard routers",
            self.requests.load(ld),
        );
        c(
            &mut out,
            "responses_total",
            "successful replies sent",
            self.responses.load(ld),
        );
        c(
            &mut out,
            "failures_total",
            "failure replies sent",
            self.failures.load(ld),
        );
        c(
            &mut out,
            "shed_total",
            "requests shed by admission control (Overloaded)",
            self.shed.load(ld),
        );
        c(
            &mut out,
            "batches_total",
            "batches dispatched to workers",
            self.batches.load(ld),
        );
        c(
            &mut out,
            "pjrt_execs_total",
            "compiled-artifact executions",
            self.pjrt_execs.load(ld),
        );
        c(
            &mut out,
            "native_execs_total",
            "native batched launches",
            self.native_execs.load(ld),
        );
        c(
            &mut out,
            "native_sparse_execs_total",
            "native launches executed by the sparse batch engine",
            self.native_sparse_execs.load(ld),
        );
        c(
            &mut out,
            "native_elems_total",
            "requests served by native launches",
            self.native_elems.load(ld),
        );
        c(
            &mut out,
            "admm_execs_total",
            "native launches executed by the ADMM engine family",
            self.admm_execs.load(ld),
        );
        c(
            &mut out,
            "admm_elems_total",
            "requests served by ADMM launches",
            self.admm_elems.load(ld),
        );
        c(
            &mut out,
            "router_admm_picks_total",
            "routed batches dispatched to the ADMM family",
            self.router_admm_picks.load(ld),
        );
        c(
            &mut out,
            "router_altdiff_picks_total",
            "routed batches kept on the Alt-Diff family",
            self.router_altdiff_picks.load(ld),
        );
        c(
            &mut out,
            "fw_execs_total",
            "native launches executed by the Frank-Wolfe engine family",
            self.fw_execs.load(ld),
        );
        c(
            &mut out,
            "fw_elems_total",
            "requests served by Frank-Wolfe launches",
            self.fw_elems.load(ld),
        );
        c(
            &mut out,
            "router_fw_picks_total",
            "routed batches dispatched to the Frank-Wolfe family",
            self.router_fw_picks.load(ld),
        );
        c(
            &mut out,
            "fw_iters_total",
            "solver iterations run by Frank-Wolfe launches",
            self.fw_iters.load(ld),
        );
        c(
            &mut out,
            "admm_iters_total",
            "solver iterations run by ADMM launches",
            self.admm_iters.load(ld),
        );
        c(
            &mut out,
            "altdiff_iters_total",
            "solver iterations run by native Alt-Diff launches",
            self.altdiff_iters.load(ld),
        );
        c(
            &mut out,
            "adjoint_execs_total",
            "adjoint (gradient) batched launches",
            self.adjoint_execs.load(ld),
        );
        c(
            &mut out,
            "adjoint_elems_total",
            "gradient requests served by adjoint launches",
            self.adjoint_elems.load(ld),
        );
        c(
            &mut out,
            "padded_slots_total",
            "slots wasted padding partial batches",
            self.padded_slots.load(ld),
        );
        c(
            &mut out,
            "warm_hits_total",
            "requests resumed from a cached warm-start iterate",
            self.warm_hits.load(ld),
        );
        c(
            &mut out,
            "warm_misses_total",
            "warm-start cache lookups that missed",
            self.warm_misses.load(ld),
        );
        c(
            &mut out,
            "warm_iters_saved_total",
            "iterations under the routed k saved by warm starts",
            self.warm_iters_saved.load(ld),
        );
        c(
            &mut out,
            "truncation_bumps_total",
            "truncation-table online corrections",
            self.bumps.load(ld),
        );
        c(
            &mut out,
            "drained_total",
            "requests answered Shutdown during a graceful drain",
            self.drained.load(ld),
        );
        c(
            &mut out,
            "deadline_shed_total",
            "requests shed DeadlineExceeded before execution",
            self.deadline_shed.load(ld),
        );
        // per-priority-class series: one HELP/TYPE per family, one
        // labeled sample per class
        let class_family = |out: &mut String,
                            name: &str,
                            help: &str,
                            rows: &[AtomicU64; 3]| {
            out.push_str(&format!(
                "# HELP altdiff_{name} {help}\n\
                 # TYPE altdiff_{name} counter\n"
            ));
            for p in Priority::ALL {
                out.push_str(&format!(
                    "altdiff_{name}{{class=\"{}\"}} {}\n",
                    p.label(),
                    rows[p.idx()].load(ld)
                ));
            }
        };
        class_family(
            &mut out,
            "class_shed_total",
            "Overloaded sheds per priority class",
            &self.shed_by_class,
        );
        class_family(
            &mut out,
            "class_deadline_shed_total",
            "DeadlineExceeded sheds per priority class",
            &self.deadline_by_class,
        );
        class_family(
            &mut out,
            "class_served_total",
            "served replies per priority class",
            &self.served_by_class,
        );
        class_family(
            &mut out,
            "class_slo_ok_total",
            "served replies within the class latency SLO",
            &self.slo_ok_by_class,
        );
        class_family(
            &mut out,
            "class_slo_miss_total",
            "served replies over the class latency SLO",
            &self.slo_miss_by_class,
        );
        g(
            &mut out,
            "queue_depth",
            "requests waiting in the dynamic batcher",
            self.queue_depth.load(ld),
        );
        g(
            &mut out,
            "net_inflight",
            "network requests admitted and not yet answered",
            self.net_inflight.load(ld),
        );
        // histogram: Prometheus buckets are cumulative
        out.push_str(
            "# HELP altdiff_latency_us end-to-end reply latency \
             (microseconds)\n\
             # TYPE altdiff_latency_us histogram\n",
        );
        let mut acc = 0u64;
        for (i, &ub) in LAT_BUCKETS_US.iter().enumerate() {
            acc += self.lat_hist[i].load(ld);
            let le = if ub == u64::MAX {
                "+Inf".to_string()
            } else {
                ub.to_string()
            };
            out.push_str(&format!(
                "altdiff_latency_us_bucket{{le=\"{le}\"}} {acc}\n"
            ));
        }
        out.push_str(&format!(
            "altdiff_latency_us_sum {}\n",
            self.total_latency_us.load(ld)
        ));
        out.push_str(&format!("altdiff_latency_us_count {acc}\n"));
        // per-(class × stage) span histograms from the tracing plane
        out.push_str(
            "# HELP altdiff_stage_latency_us per-stage request latency \
             decomposition (microseconds; only moves with stage stamps \
             enabled)\n\
             # TYPE altdiff_stage_latency_us histogram\n",
        );
        for p in Priority::ALL {
            for (si, stage) in SPAN_LABELS.iter().enumerate() {
                let mut sacc = 0u64;
                for (bi, &ub) in LAT_BUCKETS_US.iter().enumerate() {
                    sacc += self.stage_hist[p.idx()][si][bi].load(ld);
                    let le = if ub == u64::MAX {
                        "+Inf".to_string()
                    } else {
                        ub.to_string()
                    };
                    out.push_str(&format!(
                        "altdiff_stage_latency_us_bucket{{class=\"{}\",\
                         stage=\"{stage}\",le=\"{le}\"}} {sacc}\n",
                        p.label()
                    ));
                }
                out.push_str(&format!(
                    "altdiff_stage_latency_us_sum{{class=\"{}\",\
                     stage=\"{stage}\"}} {}\n",
                    p.label(),
                    self.stage_sum_us[p.idx()][si].load(ld)
                ));
                out.push_str(&format!(
                    "altdiff_stage_latency_us_count{{class=\"{}\",\
                     stage=\"{stage}\"}} {}\n",
                    p.label(),
                    self.stage_count[p.idx()].load(ld)
                ));
            }
        }
        // per-shard scheduler series: one HELP/TYPE per family, one
        // labeled sample per shard
        let shard_family =
            |out: &mut String, name: &str, help: &str, kind: &str| {
                out.push_str(&format!(
                    "# HELP altdiff_{name} {help}\n\
                     # TYPE altdiff_{name} {kind}\n"
                ));
            };
        shard_family(
            &mut out,
            "shard_queue_depth",
            "requests waiting on this shard (queue + batcher)",
            "gauge",
        );
        for (i, s) in self.shards.iter().enumerate() {
            out.push_str(&format!(
                "altdiff_shard_queue_depth{{shard=\"{i}\"}} {}\n",
                s.queue_depth.load(ld)
            ));
        }
        shard_family(
            &mut out,
            "shard_batches_total",
            "batches formed by this shard's batcher",
            "counter",
        );
        for (i, s) in self.shards.iter().enumerate() {
            out.push_str(&format!(
                "altdiff_shard_batches_total{{shard=\"{i}\"}} {}\n",
                s.batches.load(ld)
            ));
        }
        shard_family(
            &mut out,
            "shard_elems_total",
            "requests carried by this shard's formed batches",
            "counter",
        );
        for (i, s) in self.shards.iter().enumerate() {
            out.push_str(&format!(
                "altdiff_shard_elems_total{{shard=\"{i}\"}} {}\n",
                s.elems.load(ld)
            ));
        }
        shard_family(
            &mut out,
            "shard_partial_flush_total",
            "batches flushed by batch_timeout_us before max_batch",
            "counter",
        );
        for (i, s) in self.shards.iter().enumerate() {
            out.push_str(&format!(
                "altdiff_shard_partial_flush_total{{shard=\"{i}\"}} {}\n",
                s.partial_flushes.load(ld)
            ));
        }
        shard_family(
            &mut out,
            "shard_steals_total",
            "formed batches stolen from this shard by idle siblings",
            "counter",
        );
        for (i, s) in self.shards.iter().enumerate() {
            out.push_str(&format!(
                "altdiff_shard_steals_total{{shard=\"{i}\"}} {}\n",
                s.steals.load(ld)
            ));
        }
        shard_family(
            &mut out,
            "shard_stolen_elems_total",
            "requests carried by stolen batches",
            "counter",
        );
        for (i, s) in self.shards.iter().enumerate() {
            out.push_str(&format!(
                "altdiff_shard_stolen_elems_total{{shard=\"{i}\"}} {}\n",
                s.stolen_elems.load(ld)
            ));
        }
        shard_family(
            &mut out,
            "shard_shed_total",
            "requests shed Overloaded by this shard's bounded queue",
            "counter",
        );
        for (i, s) in self.shards.iter().enumerate() {
            out.push_str(&format!(
                "altdiff_shard_shed_total{{shard=\"{i}\"}} {}\n",
                s.shed.load(ld)
            ));
        }
        shard_family(
            &mut out,
            "shard_deadline_shed_total",
            "requests shed DeadlineExceeded at this shard's \
             batch-formation checkpoint",
            "counter",
        );
        for (i, s) in self.shards.iter().enumerate() {
            out.push_str(&format!(
                "altdiff_shard_deadline_shed_total{{shard=\"{i}\"}} {}\n",
                s.deadline_shed.load(ld)
            ));
        }
        shard_family(
            &mut out,
            "shard_batch_occupancy",
            "requests per formed batch, per shard",
            "histogram",
        );
        for (i, s) in self.shards.iter().enumerate() {
            let mut occ_acc = 0u64;
            for (j, &ub) in OCC_BUCKETS.iter().enumerate() {
                occ_acc += s.occ_hist[j].load(ld);
                let le = if ub == u64::MAX {
                    "+Inf".to_string()
                } else {
                    ub.to_string()
                };
                out.push_str(&format!(
                    "altdiff_shard_batch_occupancy_bucket\
                     {{shard=\"{i}\",le=\"{le}\"}} {occ_acc}\n"
                ));
            }
            out.push_str(&format!(
                "altdiff_shard_batch_occupancy_sum{{shard=\"{i}\"}} {}\n",
                s.elems.load(ld)
            ));
            out.push_str(&format!(
                "altdiff_shard_batch_occupancy_count{{shard=\"{i}\"}} {}\n",
                s.batches.load(ld)
            ));
        }
        out
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let steals: u64 = self
            .shards
            .iter()
            .map(|s| s.steals.load(Ordering::Relaxed))
            .sum();
        let pflush: u64 = self
            .shards
            .iter()
            .map(|s| s.partial_flushes.load(Ordering::Relaxed))
            .sum();
        format!(
            "req={} resp={} fail={} shed={} ddl={} batches={} pjrt={} \
             native={} sparse={} admm={} fw={} routed={}:{}:{} adjoint={} \
             native_occ={:.1} pad={} bumps={} warm={}/{} saved={} \
             shards={} steals={} pflush={} mean_lat={:.0}us p90<={}us",
            self.requests.load(Ordering::Relaxed),
            self.responses.load(Ordering::Relaxed),
            self.failures.load(Ordering::Relaxed),
            self.shed.load(Ordering::Relaxed),
            self.deadline_shed.load(Ordering::Relaxed),
            self.batches.load(Ordering::Relaxed),
            self.pjrt_execs.load(Ordering::Relaxed),
            self.native_execs.load(Ordering::Relaxed),
            self.native_sparse_execs.load(Ordering::Relaxed),
            self.admm_execs.load(Ordering::Relaxed),
            self.fw_execs.load(Ordering::Relaxed),
            self.router_altdiff_picks.load(Ordering::Relaxed),
            self.router_admm_picks.load(Ordering::Relaxed),
            self.router_fw_picks.load(Ordering::Relaxed),
            self.adjoint_execs.load(Ordering::Relaxed),
            self.native_batch_occupancy(),
            self.padded_slots.load(Ordering::Relaxed),
            self.bumps.load(Ordering::Relaxed),
            self.warm_hits.load(Ordering::Relaxed),
            self.warm_misses.load(Ordering::Relaxed),
            self.warm_iters_saved.load(Ordering::Relaxed),
            self.shards.len(),
            steals,
            pflush,
            self.mean_latency_us(),
            match self.latency_quantile_us(0.9) {
                u64::MAX => 999_999_999, // top (unbounded) bucket
                v => v,
            },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    #[test]
    fn latency_accounting() {
        let m = Metrics::new();
        m.responses.store(2, Ordering::Relaxed);
        m.observe_latency(100e-6);
        m.observe_latency(300e-6);
        assert!((m.mean_latency_us() - 200.0).abs() < 1.0);
        assert!(m.latency_quantile_us(0.5) <= 500);
        assert!(m.latency_quantile_us(1.0) >= 250);
    }

    #[test]
    fn quantiles_interpolate_within_the_winning_bucket() {
        // Four samples at 200µs all land in the (100, 250] bucket.
        // Pre-fix the quantile snapped to the bucket edge (250 for any
        // q); interpolation spreads ranks 1..=4 uniformly across the
        // bucket width instead.
        let m = Metrics::new();
        for _ in 0..4 {
            m.observe_latency(200e-6);
        }
        // p50 → rank 2 of 4 → 100 + 150·(2/4) = 175
        assert_eq!(m.latency_quantile_us(0.5), 175);
        // p25 → rank 1 of 4 → 100 + 150·(1/4) ≈ 138
        assert_eq!(m.latency_quantile_us(0.25), 138);
        // p100 → rank 4 of 4 → the bucket's upper bound
        assert_eq!(m.latency_quantile_us(1.0), 250);
        // q=0 clamps to rank 1, never panics or returns 0
        assert_eq!(m.latency_quantile_us(0.0), 138);
    }

    #[test]
    fn quantiles_across_buckets_pick_the_right_bucket() {
        let m = Metrics::new();
        m.observe_latency(10e-6); // (0, 50]
        m.observe_latency(200e-6); // (100, 250]
        // p50 → rank 1 → first bucket, rank 1 of 1 → upper bound 50
        assert_eq!(m.latency_quantile_us(0.5), 50);
        // p99 → rank 2 → second occupied bucket, rank 1 of 1 → 250
        assert_eq!(m.latency_quantile_us(0.99), 250);
    }

    #[test]
    fn overflow_bucket_is_explicit() {
        let m = Metrics::new();
        m.observe_latency(1.0); // 1s → unbounded overflow bucket
        m.observe_latency(30e-3); // 30ms → same (>25ms is +Inf here)
        assert_eq!(m.latency_quantile_us(0.9), u64::MAX);
        // summary maps the sentinel instead of printing u64::MAX
        assert!(m.summary().contains("p90<=999999999us"));
    }

    #[test]
    fn stage_histograms_bucket_by_class_and_stage() {
        let m = Metrics::new();
        // decode=10µs, admit=0, queue=300µs, sched=60µs, exec=900µs,
        // write=30µs
        m.note_stages(Priority::High, &[10, 0, 300, 60, 900, 30]);
        m.note_stages(Priority::Low, &[10, 0, 300, 60, 900, 30]);
        let hi = Priority::High.idx();
        let ld = Ordering::Relaxed;
        // queue=300 lands in the (250, 500] bucket (index 3)
        assert_eq!(m.stage_hist[hi][2][3].load(ld), 1);
        // exec=900 lands in the (500, 1000] bucket (index 4)
        assert_eq!(m.stage_hist[hi][4][4].load(ld), 1);
        assert_eq!(m.stage_sum_us[hi][4].load(ld), 900);
        assert_eq!(m.stage_count[hi].load(ld), 1);
        // untouched class rows stay zero
        assert_eq!(m.stage_count[Priority::Normal.idx()].load(ld), 0);
        let text = m.render_text();
        assert!(text.contains(
            "altdiff_stage_latency_us_bucket{class=\"high\",\
             stage=\"exec\",le=\"1000\"} 1"
        ));
        assert!(text.contains(
            "altdiff_stage_latency_us_sum{class=\"low\",\
             stage=\"queue\"} 300"
        ));
        assert!(text.contains(
            "altdiff_stage_latency_us_count{class=\"high\",\
             stage=\"decode\"} 1"
        ));
        assert_eq!(
            text.matches("# HELP").count(),
            text.matches("# TYPE").count()
        );
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::new();
        assert_eq!(m.mean_latency_us(), 0.0);
        assert_eq!(m.latency_quantile_us(0.9), 0);
        assert!(m.summary().contains("req=0"));
        assert_eq!(m.native_batch_occupancy(), 0.0);
    }

    #[test]
    fn render_text_is_prometheus_shaped() {
        let m = Metrics::new();
        m.requests.store(5, Ordering::Relaxed);
        m.responses.store(4, Ordering::Relaxed);
        m.shed.store(1, Ordering::Relaxed);
        m.queue_depth.store(3, Ordering::Relaxed);
        m.observe_latency(60e-6); // bucket le=100
        m.observe_latency(400e-6); // bucket le=500
        let text = m.render_text();
        assert!(text.contains("altdiff_requests_total 5"));
        assert!(text.contains("altdiff_responses_total 4"));
        assert!(text.contains("altdiff_shed_total 1"));
        assert!(text.contains("# TYPE altdiff_queue_depth gauge"));
        assert!(text.contains("altdiff_queue_depth 3"));
        // cumulative buckets: le=50 has 0, le=100 has 1, le=500 has 2,
        // and +Inf carries the total
        assert!(text.contains("altdiff_latency_us_bucket{le=\"50\"} 0"));
        assert!(text.contains("altdiff_latency_us_bucket{le=\"100\"} 1"));
        assert!(text.contains("altdiff_latency_us_bucket{le=\"500\"} 2"));
        assert!(text.contains("altdiff_latency_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("altdiff_latency_us_count 2"));
        // every HELP line has a TYPE line
        let helps = text.matches("# HELP").count();
        let types = text.matches("# TYPE").count();
        assert_eq!(helps, types);
    }

    #[test]
    fn native_occupancy_is_elems_per_launch() {
        let m = Metrics::new();
        m.native_execs.store(4, Ordering::Relaxed);
        m.native_elems.store(10, Ordering::Relaxed);
        assert!((m.native_batch_occupancy() - 2.5).abs() < 1e-12);
        assert!(m.summary().contains("native_occ=2.5"));
    }

    #[test]
    fn shard_slots_and_queue_depth_roll_up() {
        let m = Metrics::for_shards(3);
        assert_eq!(m.shards.len(), 3);
        m.shards[0].queue_depth.store(2, Ordering::Relaxed);
        m.shards[2].queue_depth.store(5, Ordering::Relaxed);
        m.refresh_queue_depth();
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 7);
        // Metrics::new() keeps the single-shard shape
        assert_eq!(Metrics::new().shards.len(), 1);
        assert_eq!(Metrics::for_shards(0).shards.len(), 1);
    }

    #[test]
    fn shard_batch_observation_fills_occupancy_histogram() {
        let m = Metrics::for_shards(2);
        m.shards[0].observe_batch(1); // bucket le=1
        m.shards[0].observe_batch(3); // bucket le=4
        m.shards[1].observe_batch(8); // bucket le=8
        assert_eq!(m.shards[0].batches.load(Ordering::Relaxed), 2);
        assert_eq!(m.shards[0].elems.load(Ordering::Relaxed), 4);
        assert_eq!(m.shards[0].occ_hist[0].load(Ordering::Relaxed), 1);
        assert_eq!(m.shards[0].occ_hist[2].load(Ordering::Relaxed), 1);
        assert_eq!(m.shards[1].occ_hist[3].load(Ordering::Relaxed), 1);
    }

    #[test]
    fn class_counters_reconcile_and_render_labeled() {
        let m = Metrics::new();
        m.note_shed(Priority::Low);
        m.note_shed(Priority::Low);
        m.note_shed(Priority::Normal);
        m.note_deadline_shed(Priority::High);
        m.note_served(Priority::High, 1e-3); // 1ms ≤ 5ms SLO → ok
        m.note_served(Priority::Low, 0.5); // 500ms > 100ms SLO → miss
        // globals == Σ class rows, by construction of the note_* fns
        assert_eq!(m.shed.load(Ordering::Relaxed), 3);
        let by_class: u64 = m
            .shed_by_class
            .iter()
            .map(|c| c.load(Ordering::Relaxed))
            .sum();
        assert_eq!(by_class, 3);
        assert_eq!(m.deadline_shed.load(Ordering::Relaxed), 1);
        // every shed counted its failure reply exactly once
        assert_eq!(m.failures.load(Ordering::Relaxed), 4);
        let hi = Priority::High.idx();
        let lo = Priority::Low.idx();
        assert_eq!(m.served_by_class[hi].load(Ordering::Relaxed), 1);
        assert_eq!(m.slo_ok_by_class[hi].load(Ordering::Relaxed), 1);
        assert_eq!(m.slo_miss_by_class[hi].load(Ordering::Relaxed), 0);
        assert_eq!(m.slo_miss_by_class[lo].load(Ordering::Relaxed), 1);
        let text = m.render_text();
        assert!(text
            .contains("altdiff_class_shed_total{class=\"low\"} 2"));
        assert!(text
            .contains("altdiff_class_shed_total{class=\"normal\"} 1"));
        assert!(text.contains(
            "altdiff_class_deadline_shed_total{class=\"high\"} 1"
        ));
        assert!(text
            .contains("altdiff_class_served_total{class=\"high\"} 1"));
        assert!(text
            .contains("altdiff_class_slo_miss_total{class=\"low\"} 1"));
        assert!(text.contains("altdiff_deadline_shed_total 1"));
        assert_eq!(
            text.matches("# HELP").count(),
            text.matches("# TYPE").count()
        );
        assert!(m.summary().contains("shed=3"));
        assert!(m.summary().contains("ddl=1"));
    }

    #[test]
    fn shard_shed_families_render_labeled() {
        let m = Metrics::for_shards(2);
        m.shards[1].shed.store(4, Ordering::Relaxed);
        m.shards[0].deadline_shed.store(2, Ordering::Relaxed);
        let text = m.render_text();
        assert!(text.contains("altdiff_shard_shed_total{shard=\"1\"} 4"));
        assert!(text.contains(
            "altdiff_shard_deadline_shed_total{shard=\"0\"} 2"
        ));
        assert_eq!(
            text.matches("# HELP").count(),
            text.matches("# TYPE").count()
        );
    }

    #[test]
    fn render_text_carries_labeled_shard_series() {
        let m = Metrics::for_shards(2);
        m.shards[0].observe_batch(2);
        m.shards[1].steals.store(4, Ordering::Relaxed);
        m.shards[1].stolen_elems.store(9, Ordering::Relaxed);
        m.shards[0].partial_flushes.store(1, Ordering::Relaxed);
        m.shards[1].queue_depth.store(6, Ordering::Relaxed);
        m.drained.store(3, Ordering::Relaxed);
        let text = m.render_text();
        assert!(text.contains("altdiff_drained_total 3"));
        assert!(text.contains("altdiff_shard_queue_depth{shard=\"1\"} 6"));
        assert!(text
            .contains("altdiff_shard_batches_total{shard=\"0\"} 1"));
        assert!(text.contains("altdiff_shard_elems_total{shard=\"0\"} 2"));
        assert!(text
            .contains("altdiff_shard_partial_flush_total{shard=\"0\"} 1"));
        assert!(text.contains("altdiff_shard_steals_total{shard=\"1\"} 4"));
        assert!(text
            .contains("altdiff_shard_stolen_elems_total{shard=\"1\"} 9"));
        // occupancy histogram: batch of 2 lands in le=2 and cumulates
        assert!(text.contains(
            "altdiff_shard_batch_occupancy_bucket{shard=\"0\",le=\"1\"} 0"
        ));
        assert!(text.contains(
            "altdiff_shard_batch_occupancy_bucket{shard=\"0\",le=\"2\"} 1"
        ));
        assert!(text.contains(
            "altdiff_shard_batch_occupancy_bucket{shard=\"0\",le=\"+Inf\"} 1"
        ));
        assert!(text
            .contains("altdiff_shard_batch_occupancy_sum{shard=\"0\"} 2"));
        assert!(text
            .contains("altdiff_shard_batch_occupancy_count{shard=\"0\"} 1"));
        // HELP/TYPE pairing survives the labeled families
        assert_eq!(
            text.matches("# HELP").count(),
            text.matches("# TYPE").count()
        );
        // summary mentions the shard roll-ups
        assert!(m.summary().contains("shards=2"));
        assert!(m.summary().contains("steals=4"));
        assert!(m.summary().contains("pflush=1"));
    }
}
