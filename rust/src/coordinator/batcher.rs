//! Dynamic batcher: groups compatible requests into padded batches.
//!
//! Compatibility key = (layer, family, k, is_grad): only requests
//! against the same registered layer, routed to the same engine family
//! and the same iteration count, and of the same kind (solve vs
//! adjoint-gradient) may share an executable launch — the two families
//! run different iterations, so a batch never mixes them. Flush policy:
//! a batch launches when it reaches the target batch size, or when its
//! oldest member has waited past `batch_timeout_us` (classic vLLM-style
//! deadline batching — latency bounded, and throughput recovers the MXU
//! efficiency of the batched artifact). A timeout-flushed *partial*
//! batch is an ordinary batch in every respect — same key, same routed
//! k, same execution path — only smaller; the exact-k contract does not
//! see the flush reason.
//!
//! Since the shard pool refactor each coordinator shard owns a private
//! `Batcher` on its router thread, so this type stays single-threaded
//! and lock-free; cross-shard effects (stealing) happen downstream on
//! *formed* batches, never inside the batcher.
//!
//! Layer names are interned as `Arc<str>` on first sight, so the
//! per-push hot path pays one map lookup and a refcount bump instead of
//! a heap `String` clone per request.
//!
//! [`Batcher::pending_count`] backs the per-shard `queue_depth` gauge
//! ([`super::ShardMetrics::queue_depth`], refreshed by each shard
//! router) — the backlog signal the network front end's admission
//! budget protects (see `net::server`).

use super::messages::Request;
use crate::obs::Stage;
use crate::warm::EngineFamily;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Batch of compatible requests ready for execution.
#[derive(Debug)]
pub struct Batch {
    /// Target layer (interned name).
    pub layer: Arc<str>,
    /// Engine family every member was routed to.
    pub family: EngineFamily,
    /// Routed iteration count shared by every member.
    pub k: usize,
    /// True for a batch of adjoint-gradient requests (every member
    /// carries a `grad_v` seed); solve and gradient requests never mix.
    pub grad: bool,
    /// The member requests, in arrival order.
    pub requests: Vec<Request>,
}

type Key = (Arc<str>, EngineFamily, usize, bool);

/// Keyed accumulation with deadline-based flushing.
pub struct Batcher {
    /// Flush threshold: a group launches at this many requests.
    pub max_batch: usize,
    /// Max time the oldest member of a group may wait.
    pub deadline: Duration,
    /// layer-name intern table (bounded by the number of distinct layer
    /// names ever seen; `Arc<str>: Borrow<str>` gives by-&str lookup)
    names: BTreeSet<Arc<str>>,
    pending: BTreeMap<Key, Vec<Request>>,
}

impl Batcher {
    /// Empty batcher with the given flush policy.
    pub fn new(max_batch: usize, deadline: Duration) -> Self {
        Batcher {
            max_batch,
            deadline,
            names: BTreeSet::new(),
            pending: BTreeMap::new(),
        }
    }

    /// [`Batcher::new`] with the deadline given in microseconds — the
    /// coordinator's `batch_timeout_us` knob (0 clamps to 1µs so a
    /// pending partial batch always flushes on the next router pass).
    pub fn with_timeout_us(max_batch: usize, timeout_us: u64) -> Self {
        Batcher::new(max_batch, Duration::from_micros(timeout_us.max(1)))
    }

    fn intern(&mut self, layer: &str) -> Arc<str> {
        if let Some(a) = self.names.get(layer) {
            return a.clone();
        }
        let a: Arc<str> = Arc::from(layer);
        self.names.insert(a.clone());
        a
    }

    /// Add a routed request (keyed by its own `layer` field); returns a
    /// full batch if one is ready.
    pub fn push(
        &mut self,
        family: EngineFamily,
        k: usize,
        req: Request,
    ) -> Option<Batch> {
        let name = self.intern(&req.layer);
        let key = (name, family, k, req.is_grad());
        let slot = self.pending.entry(key.clone()).or_default();
        slot.push(req);
        if slot.len() >= self.max_batch {
            let mut requests = self.pending.remove(&key).unwrap();
            stamp_formed(&mut requests);
            return Some(Batch {
                layer: key.0,
                family,
                k,
                grad: key.3,
                requests,
            });
        }
        None
    }

    fn unpack(key: Key, mut requests: Vec<Request>) -> Batch {
        stamp_formed(&mut requests);
        Batch {
            layer: key.0,
            family: key.1,
            k: key.2,
            grad: key.3,
            requests,
        }
    }

    /// Flush every group whose oldest request has exceeded the deadline.
    pub fn flush_expired(&mut self, now: Instant) -> Vec<Batch> {
        let expired: Vec<Key> = self
            .pending
            .iter()
            .filter(|(_, reqs)| {
                reqs.first()
                    .map(|r| now.duration_since(r.submitted) >= self.deadline)
                    .unwrap_or(false)
            })
            .map(|(k, _)| k.clone())
            .collect();
        expired
            .into_iter()
            .map(|key| {
                let requests = self.pending.remove(&key).unwrap();
                Batcher::unpack(key, requests)
            })
            .collect()
    }

    /// Flush everything (shutdown).
    pub fn flush_all(&mut self) -> Vec<Batch> {
        let keys: Vec<Key> = self.pending.keys().cloned().collect();
        keys.into_iter()
            .map(|key| {
                let requests = self.pending.remove(&key).unwrap();
                Batcher::unpack(key, requests)
            })
            .collect()
    }

    /// Requests currently waiting across all groups.
    pub fn pending_count(&self) -> usize {
        self.pending.values().map(|v| v.len()).sum()
    }

    /// Earliest deadline among pending groups (bounds the shard
    /// router's sleep so timeout flushes fire on time).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.pending
            .values()
            .filter_map(|v| v.first())
            .map(|r| r.submitted + self.deadline)
            .min()
    }
}

/// Stamp every member of a batch at emission — full, timeout-flushed,
/// and shutdown-flushed batches all pass through here, so the
/// `BatchFormed` stamp covers every exit path. A no-op per request
/// unless the record was enabled at admission (tracing plane).
fn stamp_formed(requests: &mut [Request]) {
    for r in requests {
        r.stamps.stamp(Stage::BatchFormed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALT: EngineFamily = EngineFamily::AltDiff;
    const ADMM: EngineFamily = EngineFamily::Admm;

    fn req(id: u64, layer: &str) -> Request {
        Request {
            id,
            layer: layer.into(),
            q: vec![],
            b: vec![],
            h: vec![],
            tol: 1e-3,
            grad_v: None,
            session: None,
            priority: super::super::messages::Priority::Normal,
            deadline_us: None,
            submitted: Instant::now(),
            stamps: crate::obs::StageStamps::off(),
            sampled: false,
            echo_stages: false,
        }
    }

    fn grad_req(id: u64, layer: &str) -> Request {
        Request { grad_v: Some(vec![1.0]), ..req(id, layer) }
    }

    #[test]
    fn fills_batch_at_max() {
        let mut b = Batcher::new(3, Duration::from_millis(100));
        assert!(b.push(ALT, 10, req(1, "l")).is_none());
        assert!(b.push(ALT, 10, req(2, "l")).is_none());
        let batch = b.push(ALT, 10, req(3, "l")).unwrap();
        assert_eq!(batch.requests.len(), 3);
        assert_eq!(b.pending_count(), 0);
    }

    #[test]
    fn never_mixes_layers_or_k() {
        let mut b = Batcher::new(2, Duration::from_millis(100));
        assert!(b.push(ALT, 10, req(1, "a")).is_none());
        assert!(b.push(ALT, 10, req(2, "b")).is_none());
        assert!(b.push(ALT, 20, req(3, "a")).is_none());
        assert_eq!(b.pending_count(), 3);
        let batch = b.push(ALT, 10, req(4, "a")).unwrap();
        assert_eq!(batch.k, 10);
        assert!(batch.requests.iter().all(|r| r.layer == "a"));
        assert_eq!(batch.requests.len(), 2);
    }

    #[test]
    fn deadline_flush() {
        let mut b = Batcher::new(10, Duration::from_millis(1));
        b.push(ALT, 10, req(1, "l"));
        let later = Instant::now() + Duration::from_millis(5);
        let flushed = b.flush_expired(later);
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].requests.len(), 1);
        assert_eq!(b.pending_count(), 0);
    }

    #[test]
    fn not_expired_not_flushed() {
        let mut b = Batcher::new(10, Duration::from_secs(60));
        b.push(ALT, 10, req(1, "l"));
        assert!(b.flush_expired(Instant::now()).is_empty());
        assert_eq!(b.pending_count(), 1);
    }

    #[test]
    fn preserves_arrival_order_within_key() {
        let mut b = Batcher::new(3, Duration::from_millis(100));
        b.push(ALT, 10, req(7, "l"));
        b.push(ALT, 10, req(8, "l"));
        let batch = b.push(ALT, 10, req(9, "l")).unwrap();
        let ids: Vec<u64> = batch.requests.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![7, 8, 9]);
    }

    #[test]
    fn flush_all_drains() {
        let mut b = Batcher::new(10, Duration::from_secs(1));
        b.push(ALT, 10, req(1, "a"));
        b.push(ALT, 20, req(2, "b"));
        let all = b.flush_all();
        assert_eq!(all.len(), 2);
        assert_eq!(b.pending_count(), 0);
        assert!(b.next_deadline().is_none());
    }

    #[test]
    fn never_mixes_solve_and_grad_requests() {
        let mut b = Batcher::new(2, Duration::from_millis(100));
        assert!(b.push(ALT, 10, req(1, "l")).is_none());
        assert!(b.push(ALT, 10, grad_req(2, "l")).is_none());
        assert_eq!(b.pending_count(), 2);
        let batch = b.push(ALT, 10, grad_req(3, "l")).unwrap();
        assert!(batch.grad);
        assert!(batch.requests.iter().all(|r| r.is_grad()));
        let batch = b.push(ALT, 10, req(4, "l")).unwrap();
        assert!(!batch.grad);
        assert!(batch.requests.iter().all(|r| !r.is_grad()));
    }

    #[test]
    fn never_mixes_engine_families() {
        let mut b = Batcher::new(2, Duration::from_millis(100));
        assert!(b.push(ALT, 10, req(1, "l")).is_none());
        assert!(b.push(ADMM, 10, req(2, "l")).is_none());
        assert_eq!(b.pending_count(), 2);
        let batch = b.push(ADMM, 10, req(3, "l")).unwrap();
        assert_eq!(batch.family, ADMM);
        assert_eq!(
            batch.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![2, 3]
        );
        let batch = b.push(ALT, 10, req(4, "l")).unwrap();
        assert_eq!(batch.family, ALT);
    }

    #[test]
    fn timeout_us_constructor_clamps_zero() {
        let b = Batcher::with_timeout_us(4, 0);
        assert_eq!(b.deadline, Duration::from_micros(1));
        let b = Batcher::with_timeout_us(4, 2_500);
        assert_eq!(b.deadline, Duration::from_micros(2_500));
    }

    #[test]
    fn timeout_flush_keeps_key_and_order() {
        // a timeout-flushed partial batch carries the same routed k and
        // family as a full one — the exact-k contract can't see the
        // flush reason
        let mut b = Batcher::with_timeout_us(8, 100);
        b.push(ADMM, 17, grad_req(3, "l"));
        b.push(ADMM, 17, grad_req(4, "l"));
        let later = Instant::now() + Duration::from_millis(5);
        let flushed = b.flush_expired(later);
        assert_eq!(flushed.len(), 1);
        assert_eq!(flushed[0].k, 17);
        assert_eq!(flushed[0].family, ADMM);
        assert!(flushed[0].grad);
        assert!(flushed[0].requests.len() < b.max_batch);
        assert_eq!(
            flushed[0].requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![3, 4]
        );
    }

    #[test]
    fn emission_stamps_batch_formed_on_every_exit_path() {
        use crate::obs::{Stage, StageStamps};
        let stamped = |id, layer: &str| {
            let mut r = req(id, layer);
            r.stamps = StageStamps::enabled();
            r
        };
        // full-batch path
        let mut b = Batcher::new(2, Duration::from_secs(1));
        b.push(ALT, 10, stamped(1, "l"));
        let batch = b.push(ALT, 10, stamped(2, "l")).unwrap();
        assert!(batch
            .requests
            .iter()
            .all(|r| r.stamps.get(Stage::BatchFormed).is_some()));
        // timeout-flush path
        b.push(ALT, 10, stamped(3, "l"));
        let later = Instant::now() + Duration::from_secs(2);
        let flushed = b.flush_expired(later);
        assert!(flushed[0].requests[0]
            .stamps
            .get(Stage::BatchFormed)
            .is_some());
        // shutdown-flush path
        b.push(ALT, 10, stamped(4, "l"));
        let all = b.flush_all();
        assert!(all[0].requests[0]
            .stamps
            .get(Stage::BatchFormed)
            .is_some());
        // disabled records stay inert
        b.push(ALT, 10, req(5, "l"));
        let all = b.flush_all();
        assert_eq!(all[0].requests[0].stamps, StageStamps::off());
    }

    #[test]
    fn interned_names_are_shared_across_batches() {
        let mut b = Batcher::new(1, Duration::from_secs(1));
        let b1 = b.push(ALT, 10, req(1, "layer")).unwrap();
        let b2 = b.push(ALT, 10, req(2, "layer")).unwrap();
        assert!(Arc::ptr_eq(&b1.layer, &b2.layer), "name not interned");
        assert_eq!(&*b1.layer, "layer");
    }
}
