//! # Alt-Diff: Alternating Differentiation for Optimization Layers
//!
//! Rust + JAX + Pallas reproduction of Sun et al., ICLR 2023.
//!
//! The crate is organized in layers (see DESIGN.md):
//! - substrates: [`linalg`], [`sparse`], [`util`], [`prob`], [`data`]
//! - the paper's algorithm: [`altdiff`] (+ comparators in [`baselines`])
//! - end-to-end learning: [`nn`] (optimization layers inside networks)
//! - serving: [`runtime`] (PJRT artifacts) + [`coordinator`] (router,
//!   batcher, truncation policy)
pub mod altdiff;
pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod linalg;
pub mod nn;
pub mod prob;
pub mod runtime;
pub mod sparse;
pub mod train;
pub mod util;

pub use error::{AltDiffError, Result};
