//! # Alt-Diff: Alternating Differentiation for Optimization Layers
//!
//! Rust + JAX + Pallas reproduction of Sun et al., ICLR 2023.
//!
//! The crate is organized in layers (see DESIGN.md):
//! - substrates: [`linalg`], [`sparse`], [`util`], [`prob`], [`data`]
//! - the paper's algorithm: [`altdiff`] (+ comparators in [`baselines`])
//! - batched execution: [`batch`] (one launch solves B instances of a
//!   registered layer, batch-major GEMMs + per-element truncation masks)
//! - end-to-end learning: [`nn`] (optimization layers inside networks)
//! - serving: [`runtime`] (PJRT artifacts) + [`coordinator`] (router,
//!   batcher, truncation policy; native fallback = one [`batch`] launch
//!   per dynamic batch)
//! - network: [`net`] (wire protocol + nonblocking TCP front end with
//!   admission control, plus clients and a load generator)
//! - tracing plane: [`obs`] (per-request stage stamps, the seeded
//!   1-in-N solver-trace sampler, the engines' per-iteration residual
//!   observer, and the lock-striped trace ring behind `GET /trace`)
//! - warm starts: [`warm`] (cross-solve iterate reuse — every engine
//!   accepts a prior (x, λ, ν) triple, and an LRU cache with staleness
//!   bounds threads it through the coordinator, the wire protocol's
//!   session keys, and the training loops)
//! - second engine family: [`admm`] (consensus-form over-relaxed ADMM
//!   behind the same solve/differentiate/batch/warm contracts; the
//!   coordinator calibrates the families per layer and routes each
//!   batch to the winner)
//! - third engine family: [`fw`] (projection-free away-step
//!   Frank–Wolfe over box/simplex/ℓ1-ball feasible sets — LMO instead
//!   of factorization + projection — same contracts, probed by the
//!   same router calibration)

// Numeric-kernel house style: explicit index loops mirror the paper's
// equations and the blocked-BLAS layout; several solver entry points
// genuinely take θ = (q, b, h) plus options.
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::type_complexity)]
#![allow(clippy::manual_memcpy)]
// Every public item carries rustdoc; CI denies regressions
// (`cargo doc --no-deps` with RUSTDOCFLAGS="-D warnings").
#![warn(missing_docs)]

pub mod admm;
pub mod altdiff;
pub mod baselines;
pub mod batch;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod fw;
pub mod linalg;
pub mod net;
pub mod nn;
pub mod obs;
pub mod prob;
pub mod runtime;
pub mod sparse;
pub mod train;
pub mod util;
pub mod warm;

pub use error::{AltDiffError, Result};
