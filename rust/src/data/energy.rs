//! Synthetic hourly electricity demand (the paper's §5.2 uses PJM data,
//! which is access-gated; see DESIGN.md §8 for the substitution argument).
//!
//! Model: daily + weekly harmonics + AR(1) noise + occasional demand
//! spikes, normalized into [0, 100] exactly as the paper describes.
//! Samples are (72h history → next 24h) pairs for predict-then-optimize.

use crate::util::rng::Pcg64;

/// A generated hourly demand trace with windowing helpers.
pub struct EnergyTrace {
    /// hourly demand, normalized to [0, 100]
    pub demand: Vec<f64>,
}

impl EnergyTrace {
    /// Generate `hours` of demand.
    pub fn generate(hours: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed);
        let mut raw = Vec::with_capacity(hours);
        let mut ar = 0.0f64;
        for t in 0..hours {
            let h = t as f64;
            // daily cycle (peak ~18:00), weekly dip on weekends
            let daily = (2.0 * std::f64::consts::PI * (h - 10.0) / 24.0)
                .sin()
                .max(-0.6);
            let weekly =
                (2.0 * std::f64::consts::PI * h / (24.0 * 7.0)).sin();
            ar = 0.85 * ar + 0.15 * rng.normal();
            let spike = if rng.uniform() < 0.005 {
                2.0 + rng.uniform() * 2.0
            } else {
                0.0
            };
            raw.push(3.0 + 1.6 * daily + 0.4 * weekly + 0.5 * ar + spike);
        }
        // normalize to [0, 100]
        let mn = raw.iter().cloned().fold(f64::MAX, f64::min);
        let mx = raw.iter().cloned().fold(f64::MIN, f64::max);
        let demand = raw
            .iter()
            .map(|&v| 100.0 * (v - mn) / (mx - mn + 1e-12))
            .collect();
        EnergyTrace { demand }
    }

    /// (history 72h, target 24h) windows, stride 24 (one sample per day).
    pub fn windows(&self) -> Vec<(Vec<f64>, Vec<f64>)> {
        let mut out = Vec::new();
        let mut start = 0;
        while start + 96 <= self.demand.len() {
            out.push((
                self.demand[start..start + 72].to_vec(),
                self.demand[start + 72..start + 96].to_vec(),
            ));
            start += 24;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalized_range_and_length() {
        let t = EnergyTrace::generate(24 * 30, 1);
        assert_eq!(t.demand.len(), 720);
        let mn = t.demand.iter().cloned().fold(f64::MAX, f64::min);
        let mx = t.demand.iter().cloned().fold(f64::MIN, f64::max);
        assert!(mn >= 0.0 && mn < 1.0);
        assert!(mx > 99.0 && mx <= 100.0);
    }

    #[test]
    fn daily_periodicity_present() {
        // autocorrelation at lag 24 should be clearly positive
        let t = EnergyTrace::generate(24 * 60, 2);
        let d = &t.demand;
        let n = d.len() - 24;
        let mean: f64 = d.iter().sum::<f64>() / d.len() as f64;
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 0..n {
            num += (d[i] - mean) * (d[i + 24] - mean);
        }
        for v in d {
            den += (v - mean) * (v - mean);
        }
        let rho = num / den;
        assert!(rho > 0.4, "lag-24 autocorrelation {rho}");
    }

    #[test]
    fn windows_shapes_and_alignment() {
        let t = EnergyTrace::generate(24 * 10, 3);
        let w = t.windows();
        assert_eq!(w.len(), 7); // 10 days → windows starting day 0..6
        for (hist, fut) in &w {
            assert_eq!(hist.len(), 72);
            assert_eq!(fut.len(), 24);
        }
        // second window starts 24h later
        assert_eq!(w[1].0[0], t.demand[24]);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = EnergyTrace::generate(100, 7).demand;
        let b = EnergyTrace::generate(100, 7).demand;
        assert_eq!(a, b);
        let c = EnergyTrace::generate(100, 8).demand;
        assert_ne!(a, c);
    }
}
