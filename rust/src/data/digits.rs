//! Procedurally rendered digit images (MNIST substitute — DESIGN.md §8).
//!
//! 16×16 seven-segment-style digits with random per-sample translation,
//! thickness jitter and pixel noise. Harder than it sounds at high noise;
//! crucially it exercises the identical training pipeline as the paper's
//! Table 6: feature net → optimization layer → softmax/NLL.

use crate::util::rng::Pcg64;

/// Image edge length (images are IMG×IMG).
pub const IMG: usize = 16;
/// Number of digit classes.
pub const NCLASS: usize = 10;

/// segments: a b c d e f g  (standard seven-segment labeling)
///    aaaa
///   f    b
///   f    b
///    gggg
///   e    c
///   e    c
///    dddd
const SEGMENTS: [[bool; 7]; 10] = [
    [true, true, true, true, true, true, false],    // 0
    [false, true, true, false, false, false, false], // 1
    [true, true, false, true, true, false, true],   // 2
    [true, true, true, true, false, false, true],   // 3
    [false, true, true, false, false, true, true],  // 4
    [true, false, true, true, false, true, true],   // 5
    [true, false, true, true, true, true, true],    // 6
    [true, true, true, false, false, false, false], // 7
    [true, true, true, true, true, true, true],     // 8
    [true, true, true, true, false, true, true],    // 9
];

/// One labeled image.
#[derive(Clone)]
pub struct DigitSample {
    /// IMG·IMG pixel intensities in [0, 1], row-major.
    pub pixels: Vec<f64>,
    /// Class label 0..NCLASS.
    pub label: usize,
}

/// Dataset generator.
pub struct Digits;

impl Digits {
    /// Render one digit with jitter controlled by `noise` ∈ [0, 1].
    pub fn render(label: usize, noise: f64, rng: &mut Pcg64) -> DigitSample {
        let mut px = vec![0.0f64; IMG * IMG];
        let segs = SEGMENTS[label % 10];
        // glyph box: rows 2..14, cols 4..12, with ±1 translation
        let dy = rng.below(3) as isize - 1;
        let dx = rng.below(3) as isize - 1;
        let mut set = |r: isize, c: isize| {
            let r = r + dy;
            let c = c + dx;
            if r >= 0 && r < IMG as isize && c >= 0 && c < IMG as isize {
                px[r as usize * IMG + c as usize] = 1.0;
            }
        };
        let (top, mid, bot) = (2isize, 8isize, 14isize);
        let (left, right) = (4isize, 11isize);
        if segs[0] {
            for c in left..=right {
                set(top, c);
            }
        }
        if segs[6] {
            for c in left..=right {
                set(mid, c);
            }
        }
        if segs[3] {
            for c in left..=right {
                set(bot, c);
            }
        }
        if segs[5] {
            for r in top..=mid {
                set(r, left);
            }
        }
        if segs[4] {
            for r in mid..=bot {
                set(r, left);
            }
        }
        if segs[1] {
            for r in top..=mid {
                set(r, right);
            }
        }
        if segs[2] {
            for r in mid..=bot {
                set(r, right);
            }
        }
        // noise: flip-ish additive
        for v in px.iter_mut() {
            let u = rng.normal() * 0.25 * noise;
            *v = (*v + u).clamp(0.0, 1.0);
        }
        DigitSample { pixels: px, label }
    }

    /// Balanced dataset of `count` samples.
    pub fn dataset(count: usize, noise: f64, seed: u64) -> Vec<DigitSample> {
        let mut rng = Pcg64::new(seed);
        (0..count)
            .map(|i| Self::render(i % NCLASS, noise, &mut rng))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_are_in_range_and_labeled() {
        let ds = Digits::dataset(50, 0.5, 1);
        assert_eq!(ds.len(), 50);
        for (i, s) in ds.iter().enumerate() {
            assert_eq!(s.label, i % 10);
            assert_eq!(s.pixels.len(), IMG * IMG);
            assert!(s.pixels.iter().all(|&v| (0.0..=1.0).contains(&v)));
        }
    }

    #[test]
    fn digits_are_distinguishable_without_noise() {
        let mut rng = Pcg64::new(2);
        let one = Digits::render(1, 0.0, &mut rng);
        let mut rng = Pcg64::new(2);
        let eight = Digits::render(8, 0.0, &mut rng);
        // 8 lights every segment; 1 only the right column
        let s1: f64 = one.pixels.iter().sum();
        let s8: f64 = eight.pixels.iter().sum();
        assert!(s8 > 2.0 * s1, "s1={s1} s8={s8}");
    }

    #[test]
    fn noise_zero_is_binary() {
        let mut rng = Pcg64::new(3);
        let d = Digits::render(5, 0.0, &mut rng);
        assert!(d.pixels.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Digits::dataset(10, 0.3, 9);
        let b = Digits::dataset(10, 0.3, 9);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.pixels, y.pixels);
        }
    }
}
