//! Synthetic datasets replacing the paper's gated/external data
//! (substitutions documented in DESIGN.md §8).

pub mod digits;
pub mod energy;

pub use digits::{DigitSample, Digits};
pub use energy::EnergyTrace;
