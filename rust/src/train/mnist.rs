//! Image classification with an embedded QP layer (paper §5.3, Table 6,
//! Fig. 4), on the synthetic-digits substitute for MNIST (DESIGN.md §8).
//!
//! Network (the paper's shape at reduced scale): feature MLP → dense QP
//! optimization layer (input = q, output = x*) → linear head → softmax.
//! The only difference between the compared models is the optimization
//! layer's differentiation backend: Alt-Diff vs OptNet (IPM + KKT).
//!
//! With the Alt-Diff backend the layer trains in reverse mode: each
//! minibatch backward is ONE batched adjoint launch
//! ([`OptLayer::backward_batch`]) — per-element Jacobians are never
//! stored, so layer memory is O(B·n) rather than O(B·n²).

use crate::data::{digits, Digits};
use crate::nn::{
    softmax_nll, Adam, Linear, Mlp, OptBackend, OptLayer,
};
use crate::nn::loss::argmax;
use crate::prob::dense_qp;
use crate::util::rng::Pcg64;
use std::time::Instant;

/// §5.3 experiment configuration.
#[derive(Clone, Debug)]
pub struct MnistConfig {
    /// Differentiation backend inside the optimization layer.
    pub backend: OptBackend,
    /// Alt-Diff truncation tolerance
    pub tol: f64,
    /// Training epochs.
    pub epochs: usize,
    /// Training-set size.
    pub train_size: usize,
    /// Test-set size.
    pub test_size: usize,
    /// optimization-layer dimension (paper: 200; scaled default 32)
    pub layer_dim: usize,
    /// equality / inequality constraint counts (paper: 50/50; scaled 8/8)
    pub layer_eq: usize,
    /// inequality constraint count (see `layer_eq`)
    pub layer_ineq: usize,
    /// Adam learning rate.
    pub lr: f64,
    /// Digit-glyph pixel noise ∈ [0, 1].
    pub noise: f64,
    /// Data/init RNG seed.
    pub seed: u64,
    /// samples pushed through the optimization layer per step: B > 1 runs
    /// ONE `BatchedAltDiff` launch per minibatch (and one optimizer step,
    /// gradient averaged); 1 reproduces per-sample SGD exactly
    pub batch_size: usize,
    /// reuse each sample's layer iterates across epochs (Alt-Diff
    /// minibatch path only): forward solves resume from the sample's
    /// previous epoch's solution and backwards from its cached adjoint
    /// seed — the per-sample features drift slowly as the network
    /// trains, exactly the warm regime (see [`crate::warm`])
    pub warm_start: bool,
}

impl Default for MnistConfig {
    fn default() -> Self {
        MnistConfig {
            backend: OptBackend::AltDiff,
            tol: 1e-3,
            epochs: 3,
            train_size: 300,
            test_size: 100,
            layer_dim: 32,
            layer_eq: 8,
            layer_ineq: 8,
            lr: 1e-3,
            noise: 0.6,
            seed: 0,
            batch_size: 1,
            warm_start: true,
        }
    }
}

/// Per-backend training outcome (one Table 6 row).
#[derive(Clone, Debug)]
pub struct MnistReport {
    /// Which backend produced this row.
    pub backend_label: String,
    /// Mean training loss per epoch.
    pub train_losses: Vec<f64>,
    /// Test accuracy per epoch.
    pub test_accs: Vec<f64>,
    /// Wallclock seconds per epoch.
    pub epoch_times: Vec<f64>,
    /// Mean solver iterations per optimization-layer call.
    pub mean_layer_iters: f64,
}

/// The classifier with an embedded optimization layer.
pub struct OptNetClassifier {
    /// Pixel → q feature extractor.
    pub features: Mlp,
    /// The embedded QP layer.
    pub optlayer: OptLayer,
    /// x* → logits head.
    pub head: Linear,
}

impl OptNetClassifier {
    /// Build the network for a configuration.
    pub fn new(cfg: &MnistConfig, rng: &mut Pcg64) -> Self {
        let d = cfg.layer_dim;
        let qp = dense_qp(d, cfg.layer_ineq, cfg.layer_eq, cfg.seed + 7);
        OptNetClassifier {
            features: Mlp::new(
                &[digits::IMG * digits::IMG, 64, d],
                rng,
            ),
            optlayer: OptLayer::new(qp, 1.0, cfg.backend, cfg.tol)
                .unwrap(),
            head: Linear::new(d, digits::NCLASS, rng),
        }
    }

    /// pixels → features → optimization layer → logits.
    pub fn forward(&mut self, pixels: &[f64]) -> Vec<f64> {
        let feat = self.features.forward(pixels);
        let x = self.optlayer.forward(&feat);
        self.head.forward(&x)
    }

    /// Reverse pass through head, optimization layer, and features.
    pub fn backward(&mut self, glogits: &[f64]) {
        let gx = self.head.backward(glogits);
        let gq = self.optlayer.backward(&gx);
        self.features.backward(&gq);
    }

    /// Reset accumulated gradients.
    pub fn zero_grad(&mut self) {
        self.features.zero_grad();
        self.head.zero_grad();
    }

    /// One Adam update over every trainable tensor.
    pub fn step(&mut self, opt: &mut Adam) {
        let mut pg: Vec<(&mut [f64], &[f64])> = Vec::new();
        for l in &mut self.features.layers {
            pg.extend(l.params_grads());
        }
        pg.extend(self.head.params_grads());
        opt.step(&mut pg);
    }
}

/// Train + evaluate; returns the per-epoch report (Table 6 / Fig. 4 data).
pub fn train_mnist(cfg: &MnistConfig) -> MnistReport {
    let mut rng = Pcg64::new(cfg.seed);
    let train = Digits::dataset(cfg.train_size, cfg.noise, cfg.seed + 1);
    let test = Digits::dataset(cfg.test_size, cfg.noise, cfg.seed + 2);
    let mut model = OptNetClassifier::new(cfg, &mut rng);
    let mut opt = Adam::new(cfg.lr);
    if cfg.warm_start && cfg.batch_size > 1 {
        // minibatch path only: batch_size 1 keeps the exact per-sample
        // seed-run semantics. One cache slot per training sample; q
        // drifts slowly across epochs, so a generous radius is right.
        model.optlayer.enable_warm_start(cfg.train_size.max(1), 1.0);
    }

    let label = match cfg.backend {
        OptBackend::AltDiff => format!("alt-diff tol={:.0e}", cfg.tol),
        OptBackend::OptNetKkt => "optnet (ipm+kkt)".to_string(),
    };
    let mut train_losses = Vec::new();
    let mut test_accs = Vec::new();
    let mut epoch_times = Vec::new();
    let mut iters_sum = 0usize;
    let mut iters_n = 0usize;

    let bs = cfg.batch_size.max(1);
    let mut order: Vec<usize> = (0..train.len()).collect();
    for _epoch in 0..cfg.epochs {
        let t0 = Instant::now();
        rng.shuffle(&mut order);
        let mut loss_sum = 0.0;
        for chunk in order.chunks(bs) {
            // pass 1: per-sample features feed ONE batched layer launch
            let feats: Vec<Vec<f64>> = chunk
                .iter()
                .map(|&i| model.features.forward(&train[i].pixels))
                .collect();
            // keyed by sample index: epoch e resumes each sample's
            // layer solve from its epoch e−1 iterate (warm cache)
            let keys: Vec<u64> =
                chunk.iter().map(|&i| i as u64).collect();
            let xs = model.optlayer.forward_batch_keyed(&feats, &keys);
            for &it in &model.optlayer.last_batch_iters {
                iters_sum += it;
                iters_n += 1;
            }
            // pass 2a: per-sample head forward/backward, collecting the
            // incoming layer gradients dL/dx* (averaged over the chunk)
            model.zero_grad();
            let inv = 1.0 / chunk.len() as f64;
            let mut gxs: Vec<Vec<f64>> =
                Vec::with_capacity(chunk.len());
            for (j, &i) in chunk.iter().enumerate() {
                let s = &train[i];
                let logits = model.head.forward(&xs[j]);
                let (loss, glog) = softmax_nll(&logits, s.label);
                loss_sum += loss;
                let glog: Vec<f64> =
                    glog.iter().map(|g| g * inv).collect();
                gxs.push(model.head.backward(&glog));
            }
            // pass 2b: ONE batched adjoint launch through the
            // optimization layer — no per-element Jacobians exist
            let gqs = model.optlayer.backward_batch(&gxs);
            // pass 2c: per-sample feature backward. The feature MLP
            // caches activations per sample, so each backward re-runs
            // its (cheap) forward first.
            for (j, &i) in chunk.iter().enumerate() {
                let s = &train[i];
                let _ = model.features.forward(&s.pixels);
                model.features.backward(&gqs[j]);
            }
            model.step(&mut opt);
        }
        train_losses.push(loss_sum / train.len() as f64);
        // eval
        let mut correct = 0usize;
        for s in &test {
            let logits = model.forward(&s.pixels);
            if argmax(&logits) == s.label {
                correct += 1;
            }
        }
        test_accs.push(correct as f64 / test.len() as f64);
        epoch_times.push(t0.elapsed().as_secs_f64());
    }

    MnistReport {
        backend_label: label,
        train_losses,
        test_accs,
        epoch_times,
        mean_layer_iters: iters_sum as f64 / iters_n.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classifier_learns_above_chance() {
        let cfg = MnistConfig {
            epochs: 2,
            train_size: 150,
            test_size: 60,
            layer_dim: 16,
            layer_eq: 4,
            layer_ineq: 4,
            noise: 0.3,
            ..Default::default()
        };
        let rep = train_mnist(&cfg);
        let acc = *rep.test_accs.last().unwrap();
        assert!(acc > 0.3, "accuracy {acc} not above chance (0.1)");
        assert!(rep.train_losses[0] > *rep.train_losses.last().unwrap());
    }

    #[test]
    fn minibatch_training_runs_and_improves() {
        let cfg = MnistConfig {
            epochs: 2,
            train_size: 120,
            test_size: 40,
            layer_dim: 16,
            layer_eq: 4,
            layer_ineq: 4,
            noise: 0.3,
            batch_size: 6,
            ..Default::default()
        };
        let rep = train_mnist(&cfg);
        assert_eq!(rep.train_losses.len(), 2);
        assert!(rep.train_losses.iter().all(|l| l.is_finite()));
        // fewer optimizer steps than per-sample SGD, but the loss must
        // still move down from the random-init cross-entropy (~ln 10)
        assert!(
            rep.train_losses.last().unwrap() < &rep.train_losses[0],
            "minibatch loss did not improve: {:?}",
            rep.train_losses
        );
        assert!(rep.mean_layer_iters >= 1.0);
    }
}
