//! End-to-end training drivers (shared by examples/ and benches/).
//!
//! - [`energy`]: predict-then-optimize energy scheduling (paper §5.2 /
//!   Fig. 2) — MLP demand forecaster trained through the scheduling QP.
//! - [`mnist`]: image classification with an embedded dense QP layer
//!   (paper §5.3 / Table 6 / Fig. 4), Alt-Diff vs OptNet backends.

pub mod energy;
pub mod mnist;

pub use energy::{train_energy, EnergyBackend, EnergyConfig, EnergyReport};
pub use mnist::{train_mnist, MnistConfig, MnistReport};
