//! Predict-then-optimize energy generation scheduling (paper §5.2).
//!
//! A 2-hidden-layer MLP maps the past 72h of demand to a 24h forecast;
//! the forecast parameterizes the scheduling QP (eq. 14)
//!     min Σ‖x_k − d_k‖²  s.t. |x_{k+1} − x_k| ≤ r
//! and training minimizes the *decision* loss (eq. 13)
//!     L = ½ Σ (x*(d̂) − x*(d))²
//! so gradients flow through the optimization layer: dL/dd̂ =
//! (∂x*/∂q)ᵀ (x*(d̂) − x*(d)) · (−2)   [q = −2 d̂].
//!
//! Backends: Alt-Diff at several truncation tolerances vs the simulated
//! CvxpyLayer pipeline — the Fig. 2 comparison.
//!
//! The Alt-Diff backend trains in **reverse mode**: forward solves are
//! Jacobian-free, and each optimizer step backpropagates through the
//! layer with the adjoint recursion (one batched adjoint launch per
//! minibatch) — dL/dq costs O(k·n²) instead of O(k·n²·d).

use crate::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use crate::baselines::conic;
use crate::batch::BatchedAltDiff;
use crate::data::EnergyTrace;
use crate::linalg::gemv_t;
use crate::nn::{mse_loss, Adam, Mlp};
use crate::prob::energy_qp;
use crate::util::rng::Pcg64;
use crate::warm::{
    fingerprint, EngineFamily, WarmStart, WarmStartCache,
};
use std::time::Instant;

/// Differentiation backend for the scheduling layer.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EnergyBackend {
    /// Alt-Diff with truncation tolerance (paper sweeps 1e-1, 1e-2, 1e-3).
    AltDiff(f64),
    /// Simulated CvxpyLayer (embedded cone program, tol 1e-3).
    CvxpyLayerSim,
}

/// §5.2 experiment configuration.
#[derive(Clone, Debug)]
pub struct EnergyConfig {
    /// Differentiation backend for the scheduling layer.
    pub backend: EnergyBackend,
    /// Training epochs.
    pub epochs: usize,
    /// Days of synthetic demand trace to train on.
    pub days: usize,
    /// Ramp limit r of the scheduling QP.
    pub ramp: f64,
    /// Adam learning rate.
    pub lr: f64,
    /// MLP hidden width.
    pub hidden: usize,
    /// Data/init RNG seed.
    pub seed: u64,
    /// samples per optimizer step; B > 1 runs the scheduling QPs of the
    /// whole minibatch as ONE `BatchedAltDiff` launch (Alt-Diff backend
    /// only), 1 reproduces per-sample training exactly
    pub batch: usize,
    /// reuse each window's scheduling-QP iterates across epochs
    /// (minibatch path only): the oracle schedule x*(d) is *identical*
    /// every epoch (its warm solve converges almost immediately from
    /// epoch 2 on), and the predicted schedule drifts slowly with the
    /// forecaster — both exactly the warm regime (see [`crate::warm`])
    pub warm_start: bool,
}

impl Default for EnergyConfig {
    fn default() -> Self {
        EnergyConfig {
            backend: EnergyBackend::AltDiff(1e-3),
            epochs: 10,
            days: 40,
            ramp: 8.0,
            lr: 1e-3,
            hidden: 64,
            seed: 0,
            batch: 1,
            warm_start: true,
        }
    }
}

/// Per-backend training outcome (one Fig. 2 curve).
#[derive(Clone, Debug)]
pub struct EnergyReport {
    /// Which backend/tolerance produced this curve.
    pub config_label: String,
    /// mean decision loss per epoch
    pub losses: Vec<f64>,
    /// wallclock seconds per epoch
    pub epoch_times: Vec<f64>,
    /// mean solver iterations per layer call (Alt-Diff only)
    pub mean_iters: f64,
    /// Total wallclock seconds for the run.
    pub total_time: f64,
}

/// Solve the scheduling QP for demand `d`, forward-only (gradients are
/// served by the adjoint backward, which needs only the final slack).
fn schedule(
    layer: &DenseAltDiff,
    demand: &[f64],
    tol: f64,
) -> crate::altdiff::Solution {
    let q: Vec<f64> = demand.iter().map(|&d| -2.0 * d).collect();
    layer.solve_with(Some(&q), None, None, &sched_opts(tol))
}

/// Forward-only options for one scheduling solve at tolerance `tol`.
fn sched_opts(tol: f64) -> Options {
    Options {
        tol,
        max_iter: 20_000,
        backward: BackwardMode::None,
        ..Default::default()
    }
}

/// Recall the warm iterate cached under window-key `key` for θ = q.
fn recall(
    c: &mut WarmStartCache,
    key: u64,
    q: &[f64],
) -> Option<WarmStart> {
    let fp = fingerprint(Some(key), q, &[], &[]);
    c.get("energy", EngineFamily::AltDiff, 0, fp, q, &[], &[])
        .map(|(w, _)| w)
}

/// Cache window-key `key`'s converged iterate for the next epoch.
fn store(c: &mut WarmStartCache, key: u64, q: &[f64], w: WarmStart) {
    let fp = fingerprint(Some(key), q, &[], &[]);
    c.put(
        "energy",
        EngineFamily::AltDiff,
        0,
        fp,
        q.to_vec(),
        vec![],
        vec![],
        w,
        None,
    );
}

/// Train the forecaster through the scheduling layer.
pub fn train_energy(cfg: &EnergyConfig) -> EnergyReport {
    let trace = EnergyTrace::generate(24 * (cfg.days + 4), cfg.seed);
    let windows = trace.windows();
    let mut rng = Pcg64::new(cfg.seed + 100);
    let mut net = Mlp::new(&[72, cfg.hidden, cfg.hidden, 24], &mut rng);
    let mut opt = Adam::new(cfg.lr);

    // the scheduling layer: structure fixed, q varies per sample
    let qp = energy_qp(&[50.0; 24], cfg.ramp).to_dense();
    let layer = DenseAltDiff::new(qp.clone(), 1.0).unwrap();

    let label = match cfg.backend {
        EnergyBackend::AltDiff(t) => format!("alt-diff tol={t:.0e}"),
        EnergyBackend::CvxpyLayerSim => "cvxpylayer-sim".to_string(),
    };
    let mut losses = Vec::new();
    let mut times = Vec::new();
    let mut iter_sum = 0usize;
    let mut iter_count = 0usize;
    let t_total = Instant::now();

    // minibatch mode: the whole chunk's scheduling QPs go through one
    // batched launch (the CvxpyLayer baseline has no batched path)
    let minibatch = if cfg.batch > 1 {
        match cfg.backend {
            EnergyBackend::AltDiff(tol) => {
                Some((BatchedAltDiff::from_dense(&layer), tol))
            }
            EnergyBackend::CvxpyLayerSim => None,
        }
    } else {
        None
    };
    // cross-epoch warm cache: two slots per window (oracle + predicted
    // schedule), keyed by window index; the oracle θ repeats exactly
    let mut wcache = (cfg.warm_start && minibatch.is_some())
        .then(|| WarmStartCache::new(2 * windows.len().max(1), 1.0));

    for _epoch in 0..cfg.epochs {
        let t0 = Instant::now();
        let mut epoch_loss = 0.0;
        if let Some((batched, tol)) = &minibatch {
            for (ci, chunk) in windows.chunks(cfg.batch).enumerate() {
                // pass 1: forecasts for the chunk
                let x_ins: Vec<Vec<f64>> = chunk
                    .iter()
                    .map(|(hist, _)| {
                        hist.iter().map(|&v| v / 100.0 - 0.5).collect()
                    })
                    .collect();
                let pred_ds: Vec<Vec<f64>> = x_ins
                    .iter()
                    .map(|x_in| {
                        net.forward(x_in)
                            .iter()
                            .map(|&v| (v + 0.5) * 100.0)
                            .collect()
                    })
                    .collect();
                // one batched launch per θ-set: oracle schedules (tight,
                // forward-only) and predicted schedules (with ∂x/∂q)
                let q_true: Vec<Vec<f64>> = chunk
                    .iter()
                    .map(|(_, d)| d.iter().map(|&v| -2.0 * v).collect())
                    .collect();
                let q_pred: Vec<Vec<f64>> = pred_ds
                    .iter()
                    .map(|d| d.iter().map(|&v| -2.0 * v).collect())
                    .collect();
                let qt: Vec<&[f64]> =
                    q_true.iter().map(|q| q.as_slice()).collect();
                let qp_: Vec<&[f64]> =
                    q_pred.iter().map(|q| q.as_slice()).collect();
                // recall last epoch's iterates per window (oracle keys
                // are even, predicted keys odd)
                let mut warms_true: Vec<Option<WarmStart>> =
                    vec![None; chunk.len()];
                let mut warms_pred: Vec<Option<WarmStart>> =
                    vec![None; chunk.len()];
                if let Some(c) = wcache.as_mut() {
                    for j in 0..chunk.len() {
                        let w = (ci * cfg.batch + j) as u64;
                        warms_true[j] = recall(c, 2 * w, &q_true[j]);
                        warms_pred[j] =
                            recall(c, 2 * w + 1, &q_pred[j]);
                    }
                }
                let sol_true = batched.solve_batch_from(
                    Some(&qt),
                    None,
                    None,
                    Some(&warms_true),
                    &Options {
                        tol: 1e-6,
                        max_iter: 20_000,
                        backward: BackwardMode::None,
                        ..Default::default()
                    },
                );
                let sol_pred = batched.solve_batch_from(
                    Some(&qp_),
                    None,
                    None,
                    Some(&warms_pred),
                    &sched_opts(*tol),
                );
                if let Some(c) = wcache.as_mut() {
                    for j in 0..chunk.len() {
                        let w = (ci * cfg.batch + j) as u64;
                        store(
                            c,
                            2 * w,
                            &q_true[j],
                            sol_true.warm_start(j),
                        );
                        store(
                            c,
                            2 * w + 1,
                            &q_pred[j],
                            sol_pred.warm_start(j),
                        );
                    }
                }
                // pass 2a: decision losses + incoming gradients dL/dx*
                let mut gxs: Vec<Vec<f64>> =
                    Vec::with_capacity(chunk.len());
                for j in 0..chunk.len() {
                    let (loss, gx) =
                        mse_loss(&sol_pred.xs[j], &sol_true.xs[j]);
                    epoch_loss += loss;
                    iter_sum += sol_pred.iters[j];
                    iter_count += 1;
                    gxs.push(gx);
                }
                // pass 2b: ONE batched adjoint launch for the whole
                // chunk — no Jacobian ever exists
                let slack_refs = sol_pred.slack_refs();
                let gx_refs: Vec<&[f64]> =
                    gxs.iter().map(|g| g.as_slice()).collect();
                let vjp = batched.batch_vjp(
                    &slack_refs,
                    &gx_refs,
                    &Options {
                        tol: *tol,
                        max_iter: 20_000,
                        ..Options::adjoint()
                    },
                );
                // pass 2c: per-sample chain rule, gradients averaged
                net.zero_grad();
                let inv = 1.0 / chunk.len() as f64;
                for j in 0..chunk.len() {
                    let gpred: Vec<f64> = vjp.grads_q[j]
                        .iter()
                        .map(|&g| -2.0 * g * 100.0 * inv)
                        .collect();
                    let _ = net.forward(&x_ins[j]); // restore caches
                    net.backward(&gpred);
                }
                let mut pg: Vec<(&mut [f64], &[f64])> = Vec::new();
                for l in &mut net.layers {
                    pg.extend(l.params_grads());
                }
                opt.step(&mut pg);
            }
            losses.push(epoch_loss / windows.len() as f64);
            times.push(t0.elapsed().as_secs_f64());
            continue;
        }
        for (hist, target_d) in &windows {
            // normalize input to stabilize the MLP
            let x_in: Vec<f64> =
                hist.iter().map(|&v| v / 100.0 - 0.5).collect();
            let pred = net.forward(&x_in);
            // forecast in demand units
            let pred_d: Vec<f64> =
                pred.iter().map(|&v| (v + 0.5) * 100.0).collect();

            // decision loss: x*(pred) vs x*(true demand)
            let x_star_true = schedule(&layer, target_d, 1e-6).x;
            let (x_star_pred, slack, iters, gq): (
                Vec<f64>,
                Option<Vec<f64>>,
                usize,
                Option<Vec<f64>>,
            ) = match cfg.backend {
                EnergyBackend::AltDiff(tol) => {
                    let sol = schedule(&layer, &pred_d, tol);
                    (sol.x, Some(sol.s), sol.iters, None)
                }
                EnergyBackend::CvxpyLayerSim => {
                    let mut qp2 = qp.clone();
                    qp2.q =
                        pred_d.iter().map(|&d| -2.0 * d).collect();
                    // CvxpyLayer's default solve accuracy (SCS eps ≈1e-5)
                    // is tighter than its *gradient* tolerance; using the
                    // loose 1e-3 here inflated its decision loss.
                    let res =
                        conic::cvxpylayer_sim(&qp2, Param::Q, 1e-5)
                            .expect("conic");
                    let (_, gx) = mse_loss(&res.x, &x_star_true);
                    let gq = gemv_t(&res.jacobian, &gx);
                    (res.x, None, res.iters, Some(gq))
                }
            };
            let (loss, gx) = mse_loss(&x_star_pred, &x_star_true);
            epoch_loss += loss;
            iter_sum += iters;
            iter_count += 1;

            // chain rule to the forecast: q = -2 d̂ → dL/dd̂ = -2 Jᵀ gx
            // via the adjoint backward (Alt-Diff) or the baseline's own
            // Jacobian, then through the output denormalization (×100).
            let gq = match (gq, slack, cfg.backend) {
                (Some(g), _, _) => g,
                (None, Some(s), EnergyBackend::AltDiff(tol)) => {
                    let opts = Options {
                        tol,
                        max_iter: 20_000,
                        ..Options::adjoint()
                    };
                    layer.vjp(&s, &gx, &opts).grad_q
                }
                _ => unreachable!("cvxpylayer computes gq inline"),
            };
            let gpred: Vec<f64> =
                gq.iter().map(|&g| -2.0 * g * 100.0).collect();

            net.zero_grad();
            net.backward(&gpred);
            let mut pg: Vec<(&mut [f64], &[f64])> = Vec::new();
            for l in &mut net.layers {
                pg.extend(l.params_grads());
            }
            opt.step(&mut pg);
        }
        losses.push(epoch_loss / windows.len() as f64);
        times.push(t0.elapsed().as_secs_f64());
    }

    EnergyReport {
        config_label: label,
        losses,
        epoch_times: times,
        mean_iters: iter_sum as f64 / iter_count.max(1) as f64,
        total_time: t_total.elapsed().as_secs_f64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn training_reduces_decision_loss() {
        let cfg = EnergyConfig {
            epochs: 6,
            days: 10,
            ..Default::default()
        };
        let rep = train_energy(&cfg);
        assert_eq!(rep.losses.len(), 6);
        let first = rep.losses[0];
        let last = *rep.losses.last().unwrap();
        assert!(
            last < 0.7 * first,
            "loss did not improve: {first} -> {last}"
        );
        assert!(rep.mean_iters > 1.0);
    }

    #[test]
    fn truncated_backend_trains_comparably() {
        // Fig. 2 claim: truncated Alt-Diff reaches ~the same loss.
        let tight = train_energy(&EnergyConfig {
            backend: EnergyBackend::AltDiff(1e-3),
            epochs: 5,
            days: 8,
            ..Default::default()
        });
        let loose = train_energy(&EnergyConfig {
            backend: EnergyBackend::AltDiff(1e-1),
            epochs: 5,
            days: 8,
            ..Default::default()
        });
        let lt = *tight.losses.last().unwrap();
        let ll = *loose.losses.last().unwrap();
        assert!(
            (ll - lt).abs() < 0.5 * lt.max(ll).max(1.0),
            "tight {lt} vs loose {ll}"
        );
        // and the loose one does fewer iterations per call
        assert!(loose.mean_iters < tight.mean_iters);
    }

    #[test]
    fn minibatch_energy_training_improves() {
        // 13 windows / batch 8 → ragged chunks (8 + 5), one batched
        // launch per chunk per θ-set, one optimizer step per chunk
        let rep = train_energy(&EnergyConfig {
            backend: EnergyBackend::AltDiff(1e-3),
            epochs: 8,
            days: 12,
            batch: 8,
            ..Default::default()
        });
        assert_eq!(rep.losses.len(), 8);
        assert!(rep.losses.iter().all(|l| l.is_finite()));
        assert!(
            rep.losses.last().unwrap() < &rep.losses[0],
            "minibatch decision loss did not improve: {:?}",
            rep.losses
        );
        assert!(rep.mean_iters > 1.0);
    }
}
