"""L2 correctness: the scanned Alt-Diff graph vs oracle and vs KKT gradient.

Validates the two theorems the artifacts rely on:
  Thm 4.2 — the Alt-Diff Jacobian converges to the implicit-KKT Jacobian;
  Thm 4.3 — truncation error in the Jacobian is O(||x_k - x*||).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (alt_diff_qp, alt_diff_qp_batched, kkt_grad_b,
                           qp_solve_kkt)
from compile.kernels import ref
from tests.util import random_qp, hinv_of

RHO = 1.0


def _cosine(a, b):
    a = np.asarray(a).ravel()
    b = np.asarray(b).ravel()
    return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-30))


@pytest.mark.parametrize("dims", [(8, 4, 2), (12, 8, 4), (20, 10, 5)])
def test_scan_matches_oracle(dims):
    n, m, p = dims
    p_mat, q, a, b, g, h = random_qp(n, m, p, seed=n)
    hinv = hinv_of(p_mat, a, g, RHO)
    x, jx, prim, dual = alt_diff_qp(hinv, a, g, q, b, h, rho=RHO, iters=30)
    st = ref.alt_diff_ref(hinv, a, g, q, b, h, RHO, 30)
    np.testing.assert_allclose(np.asarray(x), np.asarray(st[0]),
                               rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(np.asarray(jx), np.asarray(st[4]),
                               rtol=5e-4, atol=5e-5)
    assert float(prim) >= 0 and float(dual) >= 0


def test_pallas_and_jnp_paths_agree():
    n, m, p = 10, 6, 3
    p_mat, q, a, b, g, h = random_qp(n, m, p, 2)
    hinv = hinv_of(p_mat, a, g, RHO)
    xk, jxk, _, _ = alt_diff_qp(hinv, a, g, q, b, h, rho=RHO, iters=25,
                                use_pallas=True)
    xj, jxj, _, _ = alt_diff_qp(hinv, a, g, q, b, h, rho=RHO, iters=25,
                                use_pallas=False)
    np.testing.assert_allclose(np.asarray(xk), np.asarray(xj),
                               rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(np.asarray(jxk), np.asarray(jxj),
                               rtol=5e-4, atol=5e-5)


def test_jacobian_converges_to_kkt_gradient():
    """Thm 4.2: lim_k dx_k/db = dx*/db (implicit KKT differentiation)."""
    n, m, p = 10, 6, 3
    p_mat, q, a, b, g, h = random_qp(n, m, p, 4)
    hinv = hinv_of(p_mat, a, g, RHO)
    _, jx, _, _ = alt_diff_qp(hinv, a, g, q, b, h, rho=RHO, iters=400,
                              use_pallas=False)
    x, lam, nu = qp_solve_kkt(p_mat, q, a, b, g, h, iters=3000, rho=RHO)
    jkkt = kkt_grad_b(p_mat, q, a, b, g, h, x, lam, nu)
    assert _cosine(jx, jkkt) > 0.999


def test_jacobian_matches_finite_difference():
    """End-to-end check independent of the KKT machinery: perturb b."""
    n, m, p = 9, 5, 3
    p_mat, q, a, b, g, h = random_qp(n, m, p, 9)
    hinv = hinv_of(p_mat, a, g, RHO)
    iters = 400
    _, jx, _, _ = alt_diff_qp(hinv, a, g, q, b, h, rho=RHO, iters=iters,
                              use_pallas=False)
    eps = 1e-3
    fd = np.zeros((n, p), np.float32)
    for j in range(p):
        bp = b.at[j].add(eps)
        bm = b.at[j].add(-eps)
        xp, _, _, _ = alt_diff_qp(hinv, a, g, q, bp, h, rho=RHO,
                                  iters=iters, use_pallas=False)
        xm, _, _, _ = alt_diff_qp(hinv, a, g, q, bm, h, rho=RHO,
                                  iters=iters, use_pallas=False)
        fd[:, j] = (np.asarray(xp) - np.asarray(xm)) / (2 * eps)
    assert _cosine(jx, fd) > 0.995


def test_truncation_error_scales_with_x_error():
    """Thm 4.3 qualitatively: Jacobian error shrinks with iterate error."""
    n, m, p = 10, 6, 3
    p_mat, q, a, b, g, h = random_qp(n, m, p, 6)
    hinv = hinv_of(p_mat, a, g, RHO)
    xs, jxs = [], []
    for k in (10, 40, 160, 640):
        x, jx, _, _ = alt_diff_qp(hinv, a, g, q, b, h, rho=RHO, iters=k,
                                  use_pallas=False)
        xs.append(np.asarray(x))
        jxs.append(np.asarray(jx))
    xerr = [np.linalg.norm(x - xs[-1]) for x in xs[:-1]]
    jerr = [np.linalg.norm(j - jxs[-1]) for j in jxs[:-1]]
    assert jerr[0] > jerr[1] > jerr[2]           # monotone improvement
    # same order: ratio bounded (C1 of Thm 4.3), not exploding
    ratios = [je / (xe + 1e-12) for je, xe in zip(jerr, xerr)]
    assert max(ratios) < 100 * (min(ratios) + 1e-12)


def test_batched_matches_loop():
    n, m, p, bsz = 8, 4, 2, 3
    p_mat, q, a, b, g, h = random_qp(n, m, p, 8)
    hinv = hinv_of(p_mat, a, g, RHO)
    rng = np.random.default_rng(0)
    qb = jnp.asarray(rng.standard_normal((bsz, n)).astype(np.float32))
    bb = jnp.stack([b, b * 1.1, b * 0.9])
    hb = jnp.stack([h, h + 0.1, h + 0.2])
    xb, jxb, primb, dualb = alt_diff_qp_batched(
        hinv, a, g, qb, bb, hb, rho=RHO, iters=20, use_pallas=False)
    assert xb.shape == (bsz, n) and jxb.shape == (bsz, n, p)
    for i in range(bsz):
        xi, jxi, _, _ = alt_diff_qp(hinv, a, g, qb[i], bb[i], hb[i],
                                    rho=RHO, iters=20, use_pallas=False)
        np.testing.assert_allclose(np.asarray(xb[i]), np.asarray(xi),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(jxb[i]), np.asarray(jxi),
                                   rtol=1e-5, atol=1e-6)
