"""AOT pipeline smoke: artifacts lower, contain no custom calls, manifest
is consistent, and the HLO evaluates to the oracle's numbers when run back
through jax (the rust-side parity test lives in rust/tests/)."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot
from compile.kernels import ref
from tests.util import random_qp, hinv_of


def test_smoke_build(tmp_path):
    names = aot.build_all(str(tmp_path), sizes=[(8, 4, 2)], iters=[5],
                          batches=[1, 2], verbose=False)
    assert names == ["qp_n8_m4_p2_k5_b1", "qp_n8_m4_p2_k5_b2"]
    man = (tmp_path / "manifest.tsv").read_text().strip().splitlines()
    assert man[0].startswith("#")
    assert len(man) == 3
    for name in names:
        text = (tmp_path / f"{name}.hlo.txt").read_text()
        assert "ENTRY" in text
        # the serving contract: nothing the PJRT CPU client can't run
        assert "custom-call" not in text, "artifact contains a custom call"
        assert "while" in text  # the scan survived lowering as a loop


def test_manifest_row_fields(tmp_path):
    aot.build_all(str(tmp_path), sizes=[(8, 4, 2)], iters=[5], batches=[1],
                  verbose=False)
    row = (tmp_path / "manifest.tsv").read_text().strip().splitlines()[1]
    f = row.split("\t")
    assert f[0] == "qp_n8_m4_p2_k5_b1"
    assert [f[1], f[2], f[3], f[4], f[5]] == ["8", "4", "2", "5", "1"]
    ins = f[7].split(";")
    assert ins == ["8x8", "2x8", "4x8", "8", "2", "4"]


def test_lowered_variant_numerics_match_oracle():
    """Execute the lowered HLO (via jax jit of the same fn) and compare to
    the oracle — guards against lowering changing semantics."""
    n, m, p, k = 8, 4, 2, 12
    p_mat, q, a, b, g, h = random_qp(n, m, p, 42)
    hinv = hinv_of(p_mat, a, g, aot.RHO)
    import functools
    from compile.model import alt_diff_qp
    fn = jax.jit(functools.partial(alt_diff_qp, rho=aot.RHO, iters=k))
    x, jx, prim, dual = fn(hinv, a, g, q, b, h)
    st = ref.alt_diff_ref(hinv, a, g, q, b, h, aot.RHO, k)
    np.testing.assert_allclose(np.asarray(x), np.asarray(st[0]),
                               rtol=5e-4, atol=5e-5)
    np.testing.assert_allclose(np.asarray(jx), np.asarray(st[4]),
                               rtol=5e-4, atol=5e-5)
