"""Shared problem generators for the python test-suite."""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def random_qp(n: int, m: int, p: int, seed: int = 0, dtype=np.float32):
    """A well-conditioned, strictly feasible random QP.

    P = 0.1 I + M Mᵀ / n  (SPD); x0 random; b = A x0 (equalities active at
    x0); h = G x0 + |u| + 0.1 (inequalities strictly slack at x0, so the
    problem is strictly feasible and the active set at the optimum is
    data-dependent rather than degenerate).
    """
    rng = np.random.default_rng(seed)
    mmat = rng.standard_normal((n, n)).astype(dtype)
    p_mat = (0.1 * np.eye(n, dtype=dtype) + mmat @ mmat.T / n).astype(dtype)
    q = rng.standard_normal(n).astype(dtype)
    a = rng.standard_normal((p, n)).astype(dtype) / np.sqrt(n)
    g = rng.standard_normal((m, n)).astype(dtype) / np.sqrt(n)
    x0 = rng.standard_normal(n).astype(dtype)
    b = (a @ x0).astype(dtype)
    h = (g @ x0 + np.abs(rng.standard_normal(m)) + 0.1).astype(dtype)
    return (jnp.asarray(p_mat), jnp.asarray(q), jnp.asarray(a),
            jnp.asarray(b), jnp.asarray(g), jnp.asarray(h))


def hinv_of(p_mat, a, g, rho: float):
    h = p_mat + rho * (a.T @ a) + rho * (g.T @ g)
    return jnp.linalg.inv(h)
