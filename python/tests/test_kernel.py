"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal of the compile path. hypothesis sweeps
shapes and seeds; every kernel output must match the oracle to f32
accumulation noise.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.admm_step import admm_step, matvec_tiled, vmem_report
from compile.kernels.grad_step import grad_step
from tests.util import random_qp, hinv_of

RHO = 1.0
TOL = dict(rtol=2e-4, atol=2e-5)

dims = st.tuples(
    st.integers(min_value=2, max_value=24),   # n
    st.integers(min_value=1, max_value=16),   # m
    st.integers(min_value=1, max_value=8),    # p
)


def _mid_state(n, m, p, seed):
    """A plausible mid-iteration state (nonzero duals, mixed-sign slack)."""
    rng = np.random.default_rng(seed + 1000)
    f = lambda *s: jnp.asarray(rng.standard_normal(s).astype(np.float32))
    return f(n), jnp.abs(f(m)), f(p), f(m)


@settings(max_examples=30, deadline=None)
@given(dims=dims, seed=st.integers(min_value=0, max_value=2**16))
def test_admm_step_matches_ref(dims, seed):
    n, m, p = dims
    p_mat, q, a, b, g, h = random_qp(n, m, p, seed)
    hinv = hinv_of(p_mat, a, g, RHO)
    x, s, lam, nu = _mid_state(n, m, p, seed)
    got = admm_step(hinv, a, g, q, b, h, x, s, lam, nu, rho=RHO)
    want = ref.admm_step_ref(hinv, a, g, q, b, h, x, s, lam, nu, RHO)
    for gv, wv in zip(got, want):
        np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), **TOL)


@settings(max_examples=30, deadline=None)
@given(dims=dims, seed=st.integers(min_value=0, max_value=2**16))
def test_grad_step_matches_ref(dims, seed):
    n, m, p = dims
    p_mat, q, a, b, g, h = random_qp(n, m, p, seed)
    hinv = hinv_of(p_mat, a, g, RHO)
    rng = np.random.default_rng(seed + 7)
    f = lambda *s: jnp.asarray(rng.standard_normal(s).astype(np.float32))
    s1 = f(m)  # mixed signs: exercises both branches of the sgn gate
    jx, js, jl, jn = f(n, p), f(m, p), f(p, p), f(m, p)
    got = grad_step(hinv, a, g, s1, jx, js, jl, jn, rho=RHO)
    want = ref.grad_step_ref(hinv, a, g, s1, jx, js, jl, jn, RHO)
    for gv, wv in zip(got, want):
        np.testing.assert_allclose(np.asarray(gv), np.asarray(wv), **TOL)


@settings(max_examples=15, deadline=None)
@given(
    nblocks=st.integers(min_value=1, max_value=4),
    tile=st.sampled_from([4, 8, 16]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_matvec_tiled_matches_dense(nblocks, tile, seed):
    n = nblocks * tile
    rng = np.random.default_rng(seed)
    mat = jnp.asarray(rng.standard_normal((n, n)).astype(np.float32))
    vec = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    got = matvec_tiled(mat, vec, tile=tile)
    np.testing.assert_allclose(np.asarray(got), np.asarray(mat @ vec),
                               rtol=1e-4, atol=1e-5)


def test_grad_step_sgn_gate_zeroes_clamped_rows():
    """Rows of Js where the slack is clamped (s<=0) must be exactly zero."""
    n, m, p = 6, 5, 3
    p_mat, q, a, b, g, h = random_qp(n, m, p, 3)
    hinv = hinv_of(p_mat, a, g, RHO)
    s1 = jnp.asarray([0.5, 0.0, -0.2, 1.0, 0.0], jnp.float32)
    z = lambda *sh: jnp.ones(sh, jnp.float32)
    _, js1, _, _ = grad_step(hinv, a, g, s1, z(n, p), z(m, p), z(p, p),
                             z(m, p), rho=RHO)
    js1 = np.asarray(js1)
    assert np.all(js1[1] == 0) and np.all(js1[2] == 0) and np.all(js1[4] == 0)
    assert np.any(js1[0] != 0)


def test_admm_step_slack_nonnegative():
    """Invariant: the slack projection output is always >= 0."""
    for seed in range(5):
        n, m, p = 8, 6, 3
        p_mat, q, a, b, g, h = random_qp(n, m, p, seed)
        hinv = hinv_of(p_mat, a, g, RHO)
        x, s, lam, nu = _mid_state(n, m, p, seed)
        _, s1, _, _ = admm_step(hinv, a, g, q, b, h, x, s, lam, nu, rho=RHO)
        assert float(jnp.min(s1)) >= 0.0


def test_admm_fixed_point_is_qp_solution():
    """Iterating the kernel converges to a KKT point of the QP."""
    n, m, p = 10, 6, 3
    p_mat, q, a, b, g, h = random_qp(n, m, p, 11)
    hinv = hinv_of(p_mat, a, g, RHO)
    x = jnp.zeros(n)
    s = jnp.zeros(m)
    lam = jnp.zeros(p)
    nu = jnp.zeros(m)
    for _ in range(600):
        x, s, lam, nu = admm_step(hinv, a, g, q, b, h, x, s, lam, nu,
                                  rho=RHO)
    # stationarity + primal feasibility + dual feasibility
    grad = p_mat @ x + q + a.T @ lam + g.T @ nu
    assert float(jnp.linalg.norm(grad)) < 1e-3
    assert float(jnp.linalg.norm(a @ x - b)) < 1e-3
    assert float(jnp.max(g @ x - h)) < 1e-3
    assert float(jnp.min(nu)) > -1e-4


def test_vmem_report_fields():
    r = vmem_report(64, 32, 12, 40)
    assert r["fits_one_vmem_16mb"]
    assert r["mxu_macs_total"] == r["mxu_macs_per_iter"] * 40
    assert r["resident_bytes"] == (64 + 32 + 12 + 32) * 4


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.float64])
def test_kernels_dtype_sweep(dtype):
    """Kernels are dtype-polymorphic (f64 only under x64 double mode the
    interpreter still runs; result dtype must follow inputs)."""
    n, m, p = 6, 4, 2
    p_mat, q, a, b, g, h = random_qp(n, m, p, 5)
    cast = lambda v: v.astype(dtype)
    hinv = hinv_of(cast(p_mat), cast(a), cast(g), RHO)
    x, s, lam, nu = (jnp.zeros(n, dtype), jnp.zeros(m, dtype),
                     jnp.zeros(p, dtype), jnp.zeros(m, dtype))
    x1, s1, _, _ = admm_step(hinv, cast(a), cast(g), cast(q), cast(b),
                             cast(h), x, s, lam, nu, rho=RHO)
    # under default x64-disabled jax, f64 inputs degrade to f32 — accept
    # either, but forward numerics must stay finite and slack nonneg.
    assert bool(jnp.all(jnp.isfinite(x1)))
    assert float(jnp.min(s1)) >= 0.0
