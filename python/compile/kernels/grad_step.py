"""L1 Pallas kernel: fused backward (alternating differentiation) update.

Implements eq. (7a)-(7d) specialized to QP layers and theta = b. Unlike the
forward step (matrix-*vector*), the backward propagates whole Jacobian
*matrices* — (n,p), (m,p), (p,p) — so every product is a true MXU matmul.
This is where Alt-Diff's O(k n^2) backward lives: the only n×n operand is
the cached H^-1 from the forward pass (paper Appendix B.1 "Inheritance of
the Hessian matrix"); nothing (n+n_c)-dimensional is ever factorized.

The sgn(s+) gating of (7b) is a VPU row-mask fused onto the matmul output:
a row of Js is zeroed exactly when the corresponding slack coordinate is
clamped at the boundary — the differentiable relaxation of complementary
slackness that Appendix C uses to recover the KKT gradient in the limit.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _grad_kernel(hinv_ref, a_ref, g_ref, s1_ref,
                 jx_ref, js_ref, jl_ref, jn_ref,
                 jx_out, js_out, jl_out, jn_out, *, rho: float):
    a = a_ref[...]        # (p, n)
    g = g_ref[...]        # (m, n)
    s1 = s1_ref[...]      # (m, 1) — updated slack, gates (7b)
    jx = jx_ref[...]      # (n, p)
    js = js_ref[...]      # (m, p)
    jl = jl_ref[...]      # (p, p)
    jn = jn_ref[...]      # (m, p)

    p = jl.shape[0]
    eye = jnp.eye(p, dtype=jx.dtype)

    # (7a): Jx+ = -H^-1 ( A^T Jl + G^T Jn - rho A^T + rho G^T Js )
    lxb = a.T @ jl + g.T @ jn - rho * a.T + rho * (g.T @ js)
    jx1 = -(hinv_ref[...] @ lxb)
    # (7b): row-masked slack Jacobian (dh/db = 0).
    gjx = g @ jx1
    mask = (s1 > 0.0).astype(jx.dtype)          # (m, 1) broadcasts over p
    js1 = mask * (-(1.0 / rho)) * (jn + rho * gjx)
    # (7c)/(7d): dual Jacobian ascent.
    jl1 = jl + rho * (a @ jx1 - eye)
    jn1 = jn + rho * (gjx + js1)

    jx_out[...] = jx1
    js_out[...] = js1
    jl_out[...] = jl1
    jn_out[...] = jn1


def grad_step(hinv, a, g, s1, jx, js, jl, jn, *, rho: float,
              interpret: bool = True):
    """One fused backward update (7a)-(7d) w.r.t. b as a Pallas call.

    `s1` is the slack produced by the *same* iteration's forward step.
    Returns (Jx+, Js+, Jl+, Jn+).
    """
    n, p = jx.shape
    m = js.shape[0]
    dt = jx.dtype
    out_shape = (
        jax.ShapeDtypeStruct((n, p), dt),
        jax.ShapeDtypeStruct((m, p), dt),
        jax.ShapeDtypeStruct((p, p), dt),
        jax.ShapeDtypeStruct((m, p), dt),
    )
    return pl.pallas_call(
        functools.partial(_grad_kernel, rho=rho),
        out_shape=out_shape,
        interpret=interpret,
    )(hinv, a, g, s1.reshape(-1, 1), jx, js, jl, jn)
