"""L1 Pallas kernel: fused forward ADMM update for the QP layer (eq. 5a-5d).

One kernel invocation performs the entire forward ADMM iteration —
x-update (apply cached H^-1 to the assembled right-hand side), the ReLU
slack projection, and both dual ascent steps — without the iterate ever
leaving the kernel. On a real TPU the iterate block (x, s, lam, nu) stays
VMEM-resident; H^-1, A, G stream in. The ReLU projection (the paper's
"very simple operation that projects the slack variable to the nonnegative
orthant") is a VPU elementwise op fused after the MXU matvec — no separate
memory pass, which is precisely the efficiency argument of the paper vs.
generic projection operators in unrolling methods.

interpret=True everywhere: the CPU PJRT runtime cannot execute Mosaic
custom calls; interpret mode lowers to plain HLO so the same artifact runs
on the rust PJRT CPU client. TPU efficiency is *estimated* from the
BlockSpec footprint (see DESIGN.md §Hardware-Adaptation / vmem_report).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile edge on TPU; used for VMEM/roofline estimates and for the
# tiled matvec variant. interpret mode imposes no alignment requirement.
TILE = 128


def _admm_kernel(hinv_ref, a_ref, g_ref, q_ref, b_ref, h_ref,
                 x_ref, s_ref, lam_ref, nu_ref,
                 x_out, s_out, lam_out, nu_out, *, rho: float):
    """Fused (5a)-(5d). All operands in VMEM; single grid cell.

    2-D layouts: vectors are carried as (dim, 1) columns so every product
    is a plain MXU-shaped matmul and nothing relies on 1-D iota support.
    """
    a = a_ref[...]          # (p, n)
    g = g_ref[...]          # (m, n)
    q = q_ref[...]          # (n, 1)
    b = b_ref[...]          # (p, 1)
    h = h_ref[...]          # (m, 1)
    s = s_ref[...]          # (m, 1)
    lam = lam_ref[...]      # (p, 1)
    nu = nu_ref[...]        # (m, 1)

    # --- (5a): x+ = H^-1 rhs. rhs assembled with transposed matvecs (MXU).
    rhs = -q - a.T @ lam - g.T @ nu + rho * (a.T @ b) + rho * (g.T @ (h - s))
    x1 = hinv_ref[...] @ rhs
    # --- (6): closed-form slack via ReLU (VPU, fused — no extra HBM pass).
    gx = g @ x1
    s1 = jnp.maximum(-nu / rho - (gx - h), 0.0)
    # --- (5c)/(5d): dual ascent.
    lam1 = lam + rho * (a @ x1 - b)
    nu1 = nu + rho * (gx + s1 - h)

    x_out[...] = x1
    s_out[...] = s1
    lam_out[...] = lam1
    nu_out[...] = nu1


def admm_step(hinv, a, g, q, b, h, x, s, lam, nu, *, rho: float,
              interpret: bool = True):
    """One fused forward ADMM iteration (paper eq. 5a-5d) as a Pallas call.

    Vector arguments are rank-1; they are lifted to (dim, 1) columns for
    the kernel and squeezed back. Returns (x+, s+, lam+, nu+), rank-1.
    """
    n = q.shape[0]
    m = h.shape[0]
    p = b.shape[0]
    dt = q.dtype
    col = lambda v: v.reshape(-1, 1)
    out_shape = (
        jax.ShapeDtypeStruct((n, 1), dt),
        jax.ShapeDtypeStruct((m, 1), dt),
        jax.ShapeDtypeStruct((p, 1), dt),
        jax.ShapeDtypeStruct((m, 1), dt),
    )
    x1, s1, lam1, nu1 = pl.pallas_call(
        functools.partial(_admm_kernel, rho=rho),
        out_shape=out_shape,
        interpret=interpret,
    )(hinv, a, g, col(q), col(b), col(h), col(x), col(s), col(lam), col(nu))
    return x1[:, 0], s1[:, 0], lam1[:, 0], nu1[:, 0]


# --------------------------------------------------------------------------
# Tiled H^-1 apply: the BlockSpec-scheduled variant used when n exceeds one
# MXU tile. Demonstrates the HBM->VMEM schedule (grid over row-blocks of
# H^-1, rhs broadcast) that the monolithic kernel above specializes when
# everything fits in one tile.
# --------------------------------------------------------------------------

def _matvec_tile_kernel(h_ref, v_ref, o_ref):
    o_ref[...] = h_ref[...] @ v_ref[...]


def matvec_tiled(mat, vec, *, tile: int = TILE, interpret: bool = True):
    """(n,n) @ (n,) with a grid over row-blocks of `mat`.

    BlockSpec: mat tile (tile, n) streamed per grid step; vec (n, 1) is
    re-fetched per block (index_map pins it to block 0) — on TPU it stays
    VMEM-resident across the grid. Requires n % tile == 0; callers pad.
    """
    n = mat.shape[0]
    assert n % tile == 0, f"n={n} not divisible by tile={tile}"
    grid = (n // tile,)
    out = pl.pallas_call(
        _matvec_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((n, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), mat.dtype),
        interpret=interpret,
    )(mat, vec.reshape(-1, 1))
    return out[:, 0]


def vmem_report(n: int, m: int, p: int, k: int, dtype_bytes: int = 4):
    """Static VMEM-footprint + MXU-work estimate for one fused step.

    Used by DESIGN/EXPERIMENTS to argue the TPU mapping (interpret-mode
    wallclock is NOT a TPU proxy). Returns a dict with bytes resident,
    bytes streamed, and MXU MACs per iteration.
    """
    resident = (n + m + p + m) * dtype_bytes            # iterate block
    streamed = (n * n + p * n + m * n) * dtype_bytes    # Hinv, A, G
    theta = (n + p + m) * dtype_bytes                   # q, b, h
    macs = n * n + 2 * p * n + 2 * m * n + m * n        # matvec chain
    return {
        "resident_bytes": resident,
        "streamed_bytes_per_iter": streamed + theta,
        "mxu_macs_per_iter": macs,
        "mxu_macs_total": macs * k,
        "fits_one_vmem_16mb": (resident + streamed + theta) < 16 * 2**20,
    }
