"""Pure-jnp oracle for the Alt-Diff ADMM + alternating-differentiation steps.

This file is the CORRECTNESS CONTRACT for the Pallas kernels: every kernel
in this package must match the corresponding function here bit-for-bit in
f32 (up to accumulation-order noise) under pytest/hypothesis sweeps.

Math (paper eqs. (5) and (7), QP specialization, theta = b):

  QP layer:   min_x 0.5 x^T P x + q^T x   s.t.  A x = b,  G x <= h
  Augmented Lagrangian Hessian  H = P + rho A^T A + rho G^T G  (constant).

  Forward (5a-5d), with slack s >= 0 and duals lam (eq), nu (ineq):
      x+   = Hinv @ (-q - A^T lam - G^T nu + rho A^T b + rho G^T (h - s))
      s+   = relu(-nu/rho - (G x+ - h))
      lam+ = lam + rho (A x+ - b)
      nu+  = nu  + rho (G x+ + s+ - h)

  Backward (7a-7d), Jacobians w.r.t. b:  Jx (n,p), Js (m,p), Jl (p,p),
  Jn (m,p); I_p the p-identity:
      Jx+ = -Hinv @ (A^T Jl + G^T Jn - rho A^T + rho G^T Js)
      Js+ = sgn(s+) * (-(1/rho)) * (Jn + rho G Jx+)        [dh/db = 0]
      Jl+ = Jl + rho (A Jx+ - I_p)
      Jn+ = Jn + rho (G Jx+ + Js+)

At the ADMM fixed point Jx converges to dx*/db (paper Thm 4.2).
"""

from __future__ import annotations

import jax.numpy as jnp


def admm_step_ref(hinv, a, g, q, b, h, x, s, lam, nu, rho):
    """One forward ADMM update (5a)-(5d). Shapes: hinv (n,n), a (p,n),
    g (m,n), q/x (n,), b/lam (p,), h/s/nu (m,). Returns (x+, s+, lam+, nu+).
    """
    rhs = -q - a.T @ lam - g.T @ nu + rho * (a.T @ b) + rho * (g.T @ (h - s))
    x1 = hinv @ rhs
    s1 = jnp.maximum(-nu / rho - (g @ x1 - h), 0.0)
    lam1 = lam + rho * (a @ x1 - b)
    nu1 = nu + rho * (g @ x1 + s1 - h)
    return x1, s1, lam1, nu1


def grad_step_ref(hinv, a, g, s1, jx, js, jl, jn, rho):
    """One backward (alternating differentiation) update (7a)-(7d) w.r.t. b.

    `s1` is the *already updated* slack s_{k+1} (its sign pattern gates Js).
    Jacobian shapes: jx (n,p), js (m,p), jl (p,p), jn (m,p).
    """
    p = jl.shape[0]
    eye = jnp.eye(p, dtype=jx.dtype)
    jx1 = -(hinv @ (a.T @ jl + g.T @ jn - rho * a.T + rho * (g.T @ js)))
    mask = (s1 > 0.0).astype(jx.dtype)[:, None]
    js1 = mask * (-(1.0 / rho)) * (jn + rho * (g @ jx1))
    jl1 = jl + rho * (a @ jx1 - eye)
    jn1 = jn + rho * (g @ jx1 + js1)
    return jx1, js1, jl1, jn1


def fused_step_ref(hinv, a, g, q, b, h, state, rho):
    """Forward + backward fused (what the compiled scan body computes).

    state = (x, s, lam, nu, jx, js, jl, jn); returns the updated tuple.
    """
    x, s, lam, nu, jx, js, jl, jn = state
    x1, s1, lam1, nu1 = admm_step_ref(hinv, a, g, q, b, h, x, s, lam, nu, rho)
    jx1, js1, jl1, jn1 = grad_step_ref(hinv, a, g, s1, jx, js, jl, jn, rho)
    return (x1, s1, lam1, nu1, jx1, js1, jl1, jn1)


def init_state_ref(n, m, p, dtype=jnp.float32):
    """Zero-initialized ADMM + Jacobian state (paper initializes duals/slack
    at zero; Jacobians start at zero as well)."""
    return (
        jnp.zeros((n,), dtype),
        jnp.zeros((m,), dtype),
        jnp.zeros((p,), dtype),
        jnp.zeros((m,), dtype),
        jnp.zeros((n, p), dtype),
        jnp.zeros((m, p), dtype),
        jnp.zeros((p, p), dtype),
        jnp.zeros((m, p), dtype),
    )


def alt_diff_ref(hinv, a, g, q, b, h, rho, iters):
    """Run `iters` fused steps from the zero state; returns final state."""
    n = q.shape[0]
    m = h.shape[0]
    p = b.shape[0]
    state = init_state_ref(n, m, p, dtype=q.dtype)
    for _ in range(iters):
        state = fused_step_ref(hinv, a, g, q, b, h, state, rho)
    return state
