"""AOT pipeline: lower the L2 Alt-Diff graph to HLO text artifacts.

Emits, for every variant in the compiled family, `artifacts/<name>.hlo.txt`
plus a single `artifacts/manifest.tsv` the rust runtime parses at startup.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (what the published `xla` 0.1.6 crate binds) rejects
(`proto.id() <= INT_MAX`). The text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Variant naming: qp_n{n}_m{m}_p{p}_k{k}_b{batch}
  inputs : hinv (n,n) f32, a (p,n), g (m,n), q (B,n), b (B,p), h (B,m)
           (B dropped when batch == 1)
  outputs: tuple(x (B,n), jx (B,n,p), prim (B,), dual (B,))

Run: `python -m compile.aot --out-dir ../artifacts` (from python/), or via
`make artifacts` which skips the work when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile.model import alt_diff_qp, alt_diff_qp_batched

# The compiled serving family. Sizes follow the paper's n:m:p = 10:5:2
# ratio (Table 2) at artifact-friendly scale; k ladder is the truncation
# table's domain; rho fixed per family (ablated natively in rust).
SIZES = [(16, 8, 4), (32, 16, 8), (64, 32, 12)]
ITERS = [10, 20, 40, 80]
BATCHES = [1, 8]
RHO = 1.0


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(n: int, m: int, p: int, iters: int, batch: int):
    """Lower one (n,m,p,k,B) variant; returns (name, hlo_text, meta row)."""
    dt = jnp.float32
    f32 = lambda *s: jax.ShapeDtypeStruct(s, dt)
    if batch == 1:
        fn = functools.partial(alt_diff_qp, rho=RHO, iters=iters)
        specs = (f32(n, n), f32(p, n), f32(m, n), f32(n), f32(p), f32(m))
        in_shapes = [f"{n}x{n}", f"{p}x{n}", f"{m}x{n}",
                     f"{n}", f"{p}", f"{m}"]
        out_shapes = [f"{n}", f"{n}x{p}", "", ""]
    else:
        fn = functools.partial(alt_diff_qp_batched, rho=RHO, iters=iters)
        specs = (f32(n, n), f32(p, n), f32(m, n),
                 f32(batch, n), f32(batch, p), f32(batch, m))
        in_shapes = [f"{n}x{n}", f"{p}x{n}", f"{m}x{n}",
                     f"{batch}x{n}", f"{batch}x{p}", f"{batch}x{m}"]
        out_shapes = [f"{batch}x{n}", f"{batch}x{n}x{p}",
                      f"{batch}", f"{batch}"]
    lowered = jax.jit(fn).lower(*specs)
    name = f"qp_n{n}_m{m}_p{p}_k{iters}_b{batch}"
    row = "\t".join([
        name, str(n), str(m), str(p), str(iters), str(batch), str(RHO),
        ";".join(in_shapes), ";".join(out_shapes),
    ])
    return name, to_hlo_text(lowered), row


def build_all(out_dir: str, sizes=None, iters=None, batches=None,
              verbose: bool = True) -> list[str]:
    os.makedirs(out_dir, exist_ok=True)
    rows = ["# name\tn\tm\tp\tk\tbatch\trho\tin_shapes\tout_shapes"]
    names = []
    for (n, m, p) in (sizes or SIZES):
        for k in (iters or ITERS):
            for bsz in (batches or BATCHES):
                name, text, row = lower_variant(n, m, p, k, bsz)
                path = os.path.join(out_dir, f"{name}.hlo.txt")
                with open(path, "w") as f:
                    f.write(text)
                rows.append(row)
                names.append(name)
                if verbose:
                    print(f"  wrote {path} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(rows) + "\n")
    if verbose:
        print(f"manifest: {len(names)} variants -> {out_dir}/manifest.tsv")
    return names


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--smoke", action="store_true",
                    help="single tiny variant (CI/pytest)")
    args = ap.parse_args()
    if args.smoke:
        build_all(args.out_dir, sizes=[(8, 4, 2)], iters=[5], batches=[1])
    else:
        build_all(args.out_dir)


if __name__ == "__main__":
    main()
