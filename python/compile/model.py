"""L2: the Alt-Diff QP optimization layer as a JAX compute graph.

`alt_diff_qp` is the function that gets AOT-lowered into the serving
artifacts: a fixed-trip-count `lax.scan` whose body is the pair of L1
Pallas kernels (fused forward ADMM step + fused Jacobian step). Fixed k is
deliberate — truncation (paper §4.3) is a *routing* decision made by the
rust coordinator, which picks the artifact variant whose k matches the
requested tolerance via the calibrated truncation table.

Also provides `kkt_solve_and_grad`, the pure-jnp differentiate-the-KKT
reference (OptNet/CvxpyLayer semantics) used ONLY in tests — it calls
jnp.linalg.solve, which lowers to LAPACK custom calls the rust PJRT CPU
client cannot execute, so it must never be exported.

IMPORTANT for lowering: nothing here may emit custom calls. The scan body
is matmuls / elementwise only; H^-1 is an *input* (computed by the rust
linalg substrate at variant-registration time).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from compile.kernels.admm_step import admm_step
from compile.kernels.grad_step import grad_step
from compile.kernels import ref


def alt_diff_qp(hinv, a, g, q, b, h, *, rho: float, iters: int,
                interpret: bool = True, use_pallas: bool = True):
    """Solve + differentiate one QP layer instance.

    Args:
      hinv: (n,n) inverse of H = P + rho AᵀA + rho GᵀG (registration-time).
      a: (p,n) equality matrix; g: (m,n) inequality matrix.
      q, b, h: per-request parameters (theta).
      rho: ADMM penalty (baked per artifact variant).
      iters: fixed trip count k (baked per artifact variant).
      use_pallas: scan body through the L1 kernels (True) or the jnp
        oracle (False — used by tests to isolate kernel bugs).

    Returns (x, jx, prim_res, dual_res):
      x: (n,) primal solution estimate x_k.
      jx: (n,p) Jacobian dx_k/db.
      prim_res: scalar ||Ax-b|| + ||Gx+s-h|| (feasibility monitor).
      dual_res: scalar rho*||x_k - x_{k-1}|| (convergence monitor; the
        coordinator uses it to validate its truncation table online).
    """
    n = q.shape[0]
    m = h.shape[0]
    p = b.shape[0]
    dt = q.dtype
    state0 = ref.init_state_ref(n, m, p, dtype=dt)

    def body(state, _):
        x, s, lam, nu, jx, js, jl, jn = state
        if use_pallas:
            x1, s1, lam1, nu1 = admm_step(
                hinv, a, g, q, b, h, x, s, lam, nu, rho=rho,
                interpret=interpret)
            jx1, js1, jl1, jn1 = grad_step(
                hinv, a, g, s1, jx, js, jl, jn, rho=rho, interpret=interpret)
        else:
            x1, s1, lam1, nu1, jx1, js1, jl1, jn1 = ref.fused_step_ref(
                hinv, a, g, q, b, h, state, rho)
        step = jnp.linalg.norm(x1 - x)  # reduces to sqrt(sum sq): native HLO
        return (x1, s1, lam1, nu1, jx1, js1, jl1, jn1), step

    state, steps = jax.lax.scan(body, state0, None, length=iters)
    x, s, lam, nu, jx, _, _, _ = state
    prim = jnp.linalg.norm(a @ x - b) + jnp.linalg.norm(g @ x + s - h)
    dual = rho * steps[-1]
    return x, jx, prim, dual


def alt_diff_qp_batched(hinv, a, g, qb, bb, hb, *, rho: float, iters: int,
                        interpret: bool = True, use_pallas: bool = True):
    """vmap over the request batch (qb (B,n), bb (B,p), hb (B,m)).

    The structure operands (hinv, a, g) are shared across the batch —
    exactly the serving model: one registered variant, B requests.
    """
    fn = functools.partial(alt_diff_qp, rho=rho, iters=iters,
                           interpret=interpret, use_pallas=use_pallas)
    return jax.vmap(fn, in_axes=(None, None, None, 0, 0, 0))(
        hinv, a, g, qb, bb, hb)


# --------------------------------------------------------------------------
# Test-only references (never exported to artifacts).
# --------------------------------------------------------------------------

def qp_solve_kkt(p_mat, q, a, b, g, h, *, iters: int = 2000,
                 rho: float = 1.0):
    """High-accuracy QP solve by running the jnp oracle ADMM to near-fixed
    point. Test-only helper (slow, python loop)."""
    hmat = p_mat + rho * (a.T @ a) + rho * (g.T @ g)
    hinv = jnp.linalg.inv(hmat)
    st = ref.alt_diff_ref(hinv, a, g, q, b, h, rho, iters)
    return st[0], st[2], st[3]  # x, lam, nu


def kkt_grad_b(p_mat, q, a, b, g, h, x, lam, nu):
    """dx*/db by implicit differentiation of the KKT system (eq. 25),
    the OptNet/CvxpyLayer reference semantics. Test-only (LAPACK solve).

    KKT residual F(z, b) = 0 with z = (x, lam, nu):
        Px + q + A^T lam + G^T nu      = 0
        Ax - b                         = 0
        diag(nu) (Gx - h)              = 0
    dz/db = -J_z^{-1} J_b ; J_b rows: (0, -I, 0).
    """
    n = x.shape[0]
    p = b.shape[0]
    m = h.shape[0]
    dt = x.dtype
    top = jnp.concatenate([p_mat, a.T, g.T], axis=1)
    mid = jnp.concatenate(
        [a, jnp.zeros((p, p), dt), jnp.zeros((p, m), dt)], axis=1)
    bot = jnp.concatenate(
        [nu[:, None] * g, jnp.zeros((m, p), dt),
         jnp.diag(g @ x - h)], axis=1)
    jz = jnp.concatenate([top, mid, bot], axis=0)
    jb = jnp.concatenate(
        [jnp.zeros((n, p), dt), -jnp.eye(p, dtype=dt),
         jnp.zeros((m, p), dt)], axis=0)
    # Regularize: strict complementarity can make Jz singular at active-set
    # boundaries; tiny Tikhonov matches what diffcp/qpth do in practice.
    jz = jz + 1e-9 * jnp.eye(n + p + m, dtype=dt)
    dz = -jnp.linalg.solve(jz, jb)
    return dz[:n, :]
