//! END-TO-END DRIVER (paper §5.2, Fig. 2): predict-then-optimize energy
//! generation scheduling on a synthetic PJM-like demand trace.
//!
//! Trains a 72h→24h MLP forecaster *through* the ramp-constrained
//! scheduling QP with the decision loss (eq. 13), comparing Alt-Diff at
//! three truncation tolerances against the simulated CvxpyLayer pipeline,
//! and logs the loss curves + per-epoch times (the Fig. 2 panels).
//!
//! Run: cargo run --release --example energy_scheduling [--epochs 10]

use altdiff::train::{train_energy, EnergyBackend, EnergyConfig};
use altdiff::util::{Args, Table};

fn main() {
    let args = Args::parse();
    let epochs = args.get_usize("epochs", 10);
    let days = args.get_usize("days", 30);

    println!("== energy generation scheduling (predict-then-optimize) ==");
    println!("synthetic demand: {days} days, horizon 24h, history 72h\n");

    let backends = [
        EnergyBackend::AltDiff(1e-1),
        EnergyBackend::AltDiff(1e-2),
        EnergyBackend::AltDiff(1e-3),
        EnergyBackend::CvxpyLayerSim,
    ];
    let mut reports = Vec::new();
    for b in backends {
        let cfg = EnergyConfig {
            backend: b,
            epochs,
            days,
            ..Default::default()
        };
        let rep = train_energy(&cfg);
        println!(
            "{:<22} final loss {:>10.4}  total {:.2}s  mean iters {:.1}",
            rep.config_label,
            rep.losses.last().unwrap(),
            rep.total_time,
            rep.mean_iters
        );
        reports.push(rep);
    }

    // Fig. 2a: loss curves
    let mut t = Table::new(
        "Fig 2a — decision loss per epoch",
        &["epoch", "alt 1e-1", "alt 1e-2", "alt 1e-3", "cvxpy-sim"],
    );
    for e in 0..epochs {
        t.row(&[
            format!("{e}"),
            format!("{:.4}", reports[0].losses[e]),
            format!("{:.4}", reports[1].losses[e]),
            format!("{:.4}", reports[2].losses[e]),
            format!("{:.4}", reports[3].losses[e]),
        ]);
    }
    t.print();

    // Fig. 2b: average running time per epoch
    let mut t2 = Table::new(
        "Fig 2b — average epoch time (s)",
        &["backend", "time"],
    );
    for r in &reports {
        let mean =
            r.epoch_times.iter().sum::<f64>() / r.epoch_times.len() as f64;
        t2.row(&[r.config_label.clone(), format!("{mean:.3}")]);
    }
    t2.print();

    // the Fig. 2 claims, asserted
    let alt3 = *reports[2].losses.last().unwrap();
    let cvx = *reports[3].losses.last().unwrap();
    let time_alt1: f64 = reports[0].epoch_times.iter().sum();
    let time_cvx: f64 = reports[3].epoch_times.iter().sum();
    println!(
        "\nclaims: |loss(alt 1e-3) − loss(cvxpy)| / loss(cvxpy) = {:.2}%",
        100.0 * (alt3 - cvx).abs() / cvx.max(1e-9)
    );
    println!(
        "        speedup alt-diff(1e-1) vs cvxpylayer-sim: {:.1}x",
        time_cvx / time_alt1.max(1e-9)
    );
}
