//! Serve batched optimization-layer differentiation requests through the
//! L3 coordinator, exercising the full stack: router → truncation table →
//! dynamic batcher → PJRT-compiled AOT artifacts (Pallas kernels inside),
//! with the native engine as fallback. Reports latency & throughput.
//!
//! Run: cargo run --release --example serve [--requests 200] [--workers 2]
//!      [--shards S]
//!      (needs `make artifacts` for the compiled path; otherwise serves
//!       natively and says so)
//!
//! Network mode: `--net` serves the same coordinator over a loopback
//! TCP socket and drives it with pipelined wire clients
//! ([`altdiff::net`]) instead of in-process submits — the full
//! service path: codec → event loop → admission control → batcher.

use altdiff::coordinator::{Config, Coordinator, Reply};
use altdiff::net::{Client, LoadgenOpts, NetConfig, NetServer};
use altdiff::prob::dense_qp;
use altdiff::util::{Args, Pcg64};
use std::path::Path;
use std::time::{Duration, Instant};

/// `--net`: the same two-layer coordinator, served over loopback TCP
/// and driven by the pipelined load generator.
fn run_net(coord: Coordinator, nreq: usize) {
    let server =
        NetServer::bind("127.0.0.1:0", coord, NetConfig::default())
            .expect("bind loopback");
    let addr = server.local_addr().expect("local addr");
    println!("serving on {addr}");
    let handle = std::thread::spawn(move || server.run());
    let report = altdiff::net::run_loadgen(
        addr,
        &LoadgenOpts {
            requests: nreq,
            clients: 4,
            window: 8,
            grad_share: 0.25,
            ..Default::default()
        },
    )
    .expect("loadgen");
    println!("\n{}", report.render());
    let mut admin = Client::connect(addr).expect("admin connect");
    let stats = admin.stop_server().expect("stop");
    let coord = handle.join().expect("server thread");
    drop(coord);
    println!("\nserver metrics at stop:\n{stats}");
}

fn main() {
    let args = Args::parse();
    let nreq = args.get_usize("requests", 200);
    let workers = args.get_usize("workers", 2);

    let artifacts = {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.tsv").exists().then_some(dir)
    };
    println!(
        "backend: {}",
        if artifacts.is_some() {
            "pjrt (compiled artifacts) + native fallback"
        } else {
            "native only (run `make artifacts` for the compiled path)"
        }
    );

    // register two layer sizes from the compiled family
    let qp16 = dense_qp(16, 8, 4, 1);
    let qp64 = dense_qp(64, 32, 12, 2);
    let mut coord = Coordinator::builder(Config {
        workers,
        max_batch: 8,
        batch_timeout_us: 2_000,
        shards: args.get_usize("shards", 1),
        artifacts,
        ..Default::default()
    })
    .register("qp16", qp16.clone(), 1.0)
    .unwrap()
    .register("qp64", qp64.clone(), 1.0)
    .unwrap()
    .start();

    // wait for workers to finish compiling their artifact sets so the
    // measurement below is steady-state serving, not XLA compile time
    let ready = coord.wait_ready(Duration::from_secs(120));
    println!("workers ready: {ready}");

    if args.get_bool("net", false) {
        return run_net(coord, nreq);
    }

    // synthetic request trace: mixed layers, mixed tolerances
    let mut rng = Pcg64::new(0);
    let tols = [1e-1, 1e-2, 1e-3];
    let t0 = Instant::now();
    for i in 0..nreq {
        let tol = tols[rng.below(3)];
        if i % 3 == 0 {
            let s = 1.0 + 0.1 * rng.normal();
            coord.submit(
                "qp64",
                qp64.q.iter().map(|&v| v * s).collect(),
                qp64.b.clone(),
                qp64.h.clone(),
                tol,
            );
        } else {
            let s = 1.0 + 0.1 * rng.normal();
            coord.submit(
                "qp16",
                qp16.q.iter().map(|&v| v * s).collect(),
                qp16.b.clone(),
                qp16.h.clone(),
                tol,
            );
        }
    }
    let mut ok = 0;
    let mut pjrt = 0;
    let mut max_lat = 0.0f64;
    for _ in 0..nreq {
        match coord.recv_timeout(Duration::from_secs(60)) {
            Some(Reply::Ok(r)) => {
                ok += 1;
                if r.backend == "pjrt" {
                    pjrt += 1;
                }
                max_lat = max_lat.max(r.latency);
            }
            Some(Reply::Err(f)) => {
                eprintln!("request {} failed: {}", f.id, f.error)
            }
            Some(Reply::Grad(_)) => {}
            None => break,
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    println!("\nserved {ok}/{nreq} requests in {wall:.3}s");
    println!("throughput: {:.0} req/s", ok as f64 / wall);
    println!("compiled-path share: {:.0}%", 100.0 * pjrt as f64 / ok.max(1) as f64);
    println!("max latency: {:.1}ms", max_lat * 1e3);
    println!("metrics: {}", coord.metrics.summary());
}
