//! Quickstart: register a QP layer, solve + differentiate it with
//! Alt-Diff, and cross-check the gradient against implicit KKT
//! differentiation (Thm 4.2) and a finite difference.
//!
//! Run: cargo run --release --example quickstart

use altdiff::altdiff::{BackwardMode, DenseAltDiff, Options, Param};
use altdiff::baselines;
use altdiff::linalg::cosine;
use altdiff::prob::dense_qp;

fn main() -> altdiff::Result<()> {
    // a dense QP layer: min ½xᵀPx + qᵀx  s.t. Ax=b, Gx≤h
    let (n, m, p) = (50, 25, 10);
    let qp = dense_qp(n, m, p, 0);
    println!("QP layer: n={n} vars, {m} inequalities, {p} equalities");

    // 1) register (factors H = P + ρAᵀA + ρGᵀG once)
    let layer = DenseAltDiff::new(qp.clone(), 1.0)?;

    // 2) solve + differentiate w.r.t. b in one alternating loop
    let sol = layer.solve(&Options {
        tol: 1e-6,
        backward: BackwardMode::Forward(Param::B),
        ..Default::default()
    });
    println!(
        "alt-diff: {} iterations, final step {:.2e}",
        sol.iters, sol.step_rel
    );
    println!("objective value: {:.6}", qp.objective(&sol.x));
    let (eq, ineq) = qp.feasibility(&sol.x);
    println!("feasibility: ‖Ax−b‖={eq:.2e}, max(Gx−h)+={ineq:.2e}");

    // 3) compare the Jacobian with the OptNet-style KKT gradient
    let jac = sol.jacobian.as_ref().unwrap();
    let (_, jkkt, ipm_iters) =
        baselines::optnet_layer(&qp, Param::B, 1e-10)?;
    let cos = cosine(&jac.data, &jkkt.data);
    println!(
        "cosine(∂x/∂b alt-diff, ∂x/∂b KKT) = {cos:.6}  (IPM: {ipm_iters} iters)"
    );

    // 4) truncation: loosen the tolerance, watch iterations fall while the
    //    gradient stays usable (Thm 4.3)
    println!("\ntruncation sweep (paper §4.3):");
    println!("{:>8} {:>7} {:>12}", "tol", "iters", "cosine vs KKT");
    for tol in [1e-1, 1e-2, 1e-3, 1e-4] {
        let s = layer.solve(&Options {
            tol,
            backward: BackwardMode::Forward(Param::B),
            ..Default::default()
        });
        let c = cosine(&s.jacobian.unwrap().data, &jkkt.data);
        println!("{tol:>8.0e} {:>7} {c:>12.6}", s.iters);
    }

    // 5) backprop-ready VJP
    let gx: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
    let vjp = sol.vjp(&gx);
    println!("\nvjp dL/db (first 5): {:?}", &vjp[..5.min(vjp.len())]);
    println!("\nquickstart OK");
    Ok(())
}
