//! Image classification with an embedded dense QP layer (paper §5.3,
//! Table 6, Fig. 4) on the synthetic-digits MNIST substitute.
//!
//! Trains the identical network twice — optimization layer backed by
//! Alt-Diff vs by OptNet (IPM + implicit KKT) — and reports test accuracy
//! and time per epoch, plus an Alt-Diff tolerance sweep (Fig. 4's
//! truncation claim).
//!
//! Run: cargo run --release --example image_classification [--epochs 3]

use altdiff::nn::OptBackend;
use altdiff::train::{train_mnist, MnistConfig};
use altdiff::util::{Args, Table};

fn main() {
    let args = Args::parse();
    let epochs = args.get_usize("epochs", 3);
    let train_size = args.get_usize("train", 400);

    println!("== image classification with a QP optimization layer ==\n");

    let base = MnistConfig {
        epochs,
        train_size,
        test_size: 150,
        ..Default::default()
    };

    // Table 6: Alt-Diff vs OptNet
    let alt = train_mnist(&MnistConfig {
        backend: OptBackend::AltDiff,
        ..base.clone()
    });
    let opt = train_mnist(&MnistConfig {
        backend: OptBackend::OptNetKkt,
        ..base.clone()
    });

    let mut t = Table::new(
        "Table 6 — accuracy & time per epoch",
        &["model", "test acc (%)", "time/epoch (s)", "layer iters"],
    );
    for r in [&opt, &alt] {
        let acc = 100.0 * r.test_accs.last().unwrap();
        let tm = r.epoch_times.iter().sum::<f64>()
            / r.epoch_times.len() as f64;
        t.row(&[
            r.backend_label.clone(),
            format!("{acc:.2}"),
            format!("{tm:.3}"),
            format!("{:.1}", r.mean_layer_iters),
        ]);
    }
    t.print();

    // Fig. 4: tolerance sweep for Alt-Diff
    let mut t2 = Table::new(
        "Fig 4 — alt-diff truncation sweep",
        &["tol", "final test acc (%)", "time/epoch (s)"],
    );
    for tol in [1e-1, 1e-2, 1e-3] {
        let r = train_mnist(&MnistConfig {
            backend: OptBackend::AltDiff,
            tol,
            ..base.clone()
        });
        t2.row(&[
            format!("{tol:.0e}"),
            format!("{:.2}", 100.0 * r.test_accs.last().unwrap()),
            format!(
                "{:.3}",
                r.epoch_times.iter().sum::<f64>()
                    / r.epoch_times.len() as f64
            ),
        ]);
    }
    t2.print();

    let speedup = (opt.epoch_times.iter().sum::<f64>())
        / (alt.epoch_times.iter().sum::<f64>()).max(1e-9);
    println!("\nalt-diff epoch speedup over optnet: {speedup:.2}x");
    println!(
        "accuracy parity: optnet {:.1}% vs alt-diff {:.1}%",
        100.0 * opt.test_accs.last().unwrap(),
        100.0 * alt.test_accs.last().unwrap()
    );
}
